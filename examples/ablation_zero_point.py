"""Reproduce the paper's core finding interactively: quantizing the second
moment with a zero-containing mapping destabilizes training; zero-excluding
mappings fix it (Tab. 1 / Fig. 3 in miniature).

Written against the composable transform API: the ablation swaps ONE piece
of the chain (the second-moment ``QuantPolicy`` handed to ``compressed``)
while the update rule, weight decay, and schedule stay fixed.

    PYTHONPATH=src python examples/ablation_zero_point.py
"""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax

from benchmarks.common import train_small_lm
from repro.core.optimizers import (
    QuantPolicy,
    add_decayed_weights,
    chain,
    compressed,
    scale_by_adam,
    scale_by_learning_rate,
)
from repro.core.optimizers.adamw import M_4BIT
from repro.core.quantizer import QuantConfig

for mapping in ("de", "de0", "linear"):
    v_cfg = QuantConfig(bits=4, normalization="blockwise", block_size=128,
                        mapping=mapping, signed=False)
    tx = chain(
        compressed(
            scale_by_adam(),
            {"m": QuantPolicy(config=M_4BIT, threshold=0),
             "v": QuantPolicy(config=v_cfg, threshold=0)},
        ),
        add_decayed_weights(0.01),
        scale_by_learning_rate(3e-3),
    )
    r = train_small_lm(tx, steps=120)
    tag = "zero in map" if mapping == "de" else "zero excluded"
    print(f"2nd moment 4-bit {mapping:6s} ({tag}): final_loss={r['loss_final']:.4f} "
          f"max|dW|={r['max_param_delta']:.3f} unstable={bool(r['unstable'])}")
