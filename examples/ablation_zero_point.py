"""Reproduce the paper's core finding interactively: quantizing the second
moment with a zero-containing mapping destabilizes training; zero-excluding
mappings fix it (Tab. 1 / Fig. 3 in miniature).

    PYTHONPATH=src python examples/ablation_zero_point.py
"""

import jax

from benchmarks.common import train_small_lm
from repro.core.optimizers import QuantPolicy, quantized_adamw
from repro.core.optimizers.adamw import M_4BIT
from repro.core.quantizer import QuantConfig

for mapping in ("de", "de0", "linear"):
    v_cfg = QuantConfig(bits=4, normalization="blockwise", block_size=128,
                        mapping=mapping, signed=False)
    opt = quantized_adamw(
        3e-3,
        m_policy=QuantPolicy(config=M_4BIT, threshold=0),
        v_policy=QuantPolicy(config=v_cfg, threshold=0),
    )
    r = train_small_lm(opt, steps=120)
    tag = "zero in map" if mapping == "de" else "zero excluded"
    print(f"2nd moment 4-bit {mapping:6s} ({tag}): final_loss={r['loss_final']:.4f} "
          f"max|dW|={r['max_param_delta']:.3f} unstable={bool(r['unstable'])}")
