"""End-to-end driver: train a ~100M-param LM for a few hundred steps with the
4-bit optimizer, checkpointing + restart included.

    PYTHONPATH=src python examples/train_100m.py --steps 200

~100M params: 12L x d768 x ff3072, vocab 50304 (GPT-2-small-like geometry).
On CPU this is slow; --steps 20 demonstrates the full path.
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core.optimizers import linear_warmup_linear_decay, make_optimizer, state_nbytes
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import LayerSpec, ModelConfig, init_model
from repro.train.checkpoint import CheckpointManager
from repro.train.train_loop import build_train_step, make_train_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = ModelConfig(
        name="lm-100m", num_layers=12, d_model=768, num_heads=12,
        num_kv_heads=12, head_dim=64, d_ff=3072, vocab_size=50304,
        blocks=(LayerSpec("dense", 0),) * 12, gated_mlp=False, remat=False,
    )
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(p.size) for p in jax.tree_util.tree_leaves(params))
    print(f"model: {n_params/1e6:.1f}M params")

    opt = make_optimizer("adamw4bit", linear_warmup_linear_decay(3e-4, 20, args.steps))
    state = make_train_state(params, opt)
    print(f"4-bit optimizer state: {state_nbytes(state.opt_state)/1e6:.1f} MB "
          f"(fp32 would be {n_params*8/1e6:.1f} MB)")

    step_fn = jax.jit(build_train_step(cfg, opt), donate_argnums=(0,))
    data = SyntheticLM(DataConfig(cfg.vocab_size, args.seq, args.batch, seed=0))
    mgr = CheckpointManager(args.ckpt_dir, keep_last=2)

    start = mgr.latest_step() or 0
    if start:
        print(f"restoring from checkpoint step {start}")
        state, _ = mgr.restore(jax.eval_shape(lambda: state))

    t0 = time.perf_counter()
    for t in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(t).items()}
        state, metrics = step_fn(state, batch)
        if (t + 1) % args.ckpt_every == 0:
            mgr.save(t + 1, state)
        if t % 10 == 0:
            dt = (time.perf_counter() - t0) / max(1, t - start + 1)
            print(f"step {t:4d}  loss {float(metrics['loss']):.4f}  "
                  f"grad_norm {float(metrics['grad_norm']):.3f}  {dt*1e3:.0f} ms/step")
    mgr.wait()
    print("done.")


if __name__ == "__main__":
    main()
