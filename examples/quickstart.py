"""Quickstart: swap 32-bit AdamW for the paper's 4-bit AdamW on a small LM.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs import reduced_config
from repro.core.optimizers import make_optimizer, state_nbytes
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import init_model
from repro.train.train_loop import build_train_step, make_train_state


def train(optimizer, steps=40):
    cfg = reduced_config("internlm2-1.8b")
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    state = make_train_state(params, optimizer)
    step_fn = jax.jit(build_train_step(cfg, optimizer))
    data = SyntheticLM(DataConfig(cfg.vocab_size, 64, 8, seed=0))
    for t in range(steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(t).items()}
        state, metrics = step_fn(state, batch)
        if t % 10 == 0:
            print(f"  step {t:3d}  loss {float(metrics['loss']):.4f}")
    return state


def main():
    for name, opt in (("32-bit AdamW", make_optimizer("adamw32", 3e-3)),
                      ("4-bit AdamW (paper)", make_optimizer("adamw4bit", 3e-3))):
        print(f"== {name} ==")
        state = train(opt)
        print(f"  optimizer-state bytes: {state_nbytes(state.opt_state):,}")


if __name__ == "__main__":
    main()
