"""Batched serving with continuous batching over a reduced-config model.

    PYTHONPATH=src python examples/serve_decode.py --arch gemma2-2b --weights q4
"""

import argparse

import jax

from repro.configs import ARCHS, reduced_config
from repro.models import init_model
from repro.serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b", choices=list(ARCHS))
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--weights", default="bf16", choices=("bf16", "q4"))
    ap.add_argument("--temperature", type=float, default=0.7)
    ap.add_argument("--top-k", type=int, default=20)
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    if cfg.family == "encdec" or cfg.input_mode == "embeds":
        raise SystemExit(f"{args.arch}: use a token-decoder arch for this demo")
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_batch=3, s_max=256, weights=args.weights)

    reqs = [
        Request(rid=i, prompt=[1 + i, 2 + i, 3 + i], max_new_tokens=8,
                temperature=args.temperature, top_k=args.top_k)
        for i in range(args.requests)
    ]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r in reqs:
        print(f"  rid={r.rid}: {r.output}")
    rep = eng.weight_bytes()
    print(
        f"served {args.requests} requests with continuous batching "
        f"(slots={eng.max_batch}, weights={rep['format']}, "
        f"{rep['total_serve_bytes']:,} weight bytes)"
    )


if __name__ == "__main__":
    main()
