#!/usr/bin/env python
"""Benchmark-drift gate: production4bit quality/memory vs the tracked baseline.

Regenerates the fast production benchmark rows (``benchmarks.drift``) and
compares them against ``benchmarks/results/baseline.json``:

    python scripts_check_drift.py            # check, exit 1 on drift
    python scripts_check_drift.py --update   # rewrite the baseline in place

Run from the repo root with ``PYTHONPATH=src`` (the CI bench-drift job does
exactly this).  Intentional changes to the production preset regenerate the
baseline with ``--update`` and commit the diff — the JSON diff *is* the
review artifact for quality/memory movement.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from benchmarks import drift  # noqa: E402

DEFAULT_BASELINE = os.path.join("benchmarks", "results", "baseline.json")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--steps", type=int, default=drift.DEFAULT_STEPS)
    ap.add_argument(
        "--update", action="store_true", help="rewrite the baseline file"
    )
    args = ap.parse_args()

    current = drift.production_metrics(steps=args.steps)
    print("current production metrics:")
    print(json.dumps(current, indent=2))

    if args.update:
        os.makedirs(os.path.dirname(args.baseline), exist_ok=True)
        with open(args.baseline, "w") as f:
            json.dump(current, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"baseline written: {args.baseline}")
        return 0

    if not os.path.exists(args.baseline):
        print(
            f"FAIL: no baseline at {args.baseline}; create one with --update",
            file=sys.stderr,
        )
        return 1
    with open(args.baseline) as f:
        baseline = json.load(f)

    violations = drift.compare(current, baseline)
    if violations:
        print("\nDRIFT DETECTED vs", args.baseline, file=sys.stderr)
        for v in violations:
            print(" -", v, file=sys.stderr)
        return 1
    print(f"\nOK: within tolerance of {args.baseline}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
