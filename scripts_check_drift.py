#!/usr/bin/env python
"""Benchmark-drift gate: production4bit quality/memory vs the tracked baseline.

Regenerates the fast production benchmark rows (``benchmarks.drift``) and
compares them against ``benchmarks/results/baseline.json``:

    python scripts_check_drift.py            # check, exit 1 on drift
    python scripts_check_drift.py --update   # rewrite the baseline in place

Run from the repo root with ``PYTHONPATH=src`` (the CI bench-drift job does
exactly this).  Intentional changes to the production preset regenerate the
baseline with ``--update`` and commit the diff — the JSON diff *is* the
review artifact for quality/memory movement.

Every run also writes the freshly measured metrics to
``benchmarks/results/BENCH_drift.json`` (uploaded as a CI artifact, so each
PR carries its own point on the perf trajectory) and — when
``$GITHUB_STEP_SUMMARY`` is set — renders the production4bit-vs-adamw32
comparison table into the workflow step summary.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from benchmarks import drift  # noqa: E402

DEFAULT_BASELINE = os.path.join("benchmarks", "results", "baseline.json")
BENCH_OUT = os.path.join("benchmarks", "results", "BENCH_drift.json")


def _write_step_summary(current, baseline, violations) -> None:
    """Render the comparison table into $GITHUB_STEP_SUMMARY (no-op locally)."""
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if not path:
        return
    q, m = current["quality"], current["memory"]
    bq = baseline["quality"] if baseline else None
    lines = [
        "## bench-drift: production4bit vs adamw32",
        "",
        "| metric | adamw32 | production4bit | delta |",
        "|---|---|---|---|",
        (
            f"| final loss ({current['meta']['steps']} steps) "
            f"| {q['adamw32_loss']:.4f} | {q['production4bit_loss']:.4f} "
            f"| gap {q['gap']:+.4f}"
            + (f" (baseline {bq['gap']:+.4f})" if bq else "")
            + " |"
        ),
        (
            f"| state bytes (GPT-2-M tree, {m['n_params']:,} params) "
            f"| {m['adamw32_state_bytes']:,} "
            f"| {m['production4bit_state_bytes']:,} "
            f"| ratio {m['ratio']:.4f} |"
        ),
    ]
    st = current.get("stacked")
    if st:
        lines += [
            "",
            f"Stacked-leaf fused update (L={st['L']}, {st['R']}x{st['C']}): "
            f"**{st['launch_count']} Pallas launch(es)**, "
            f"{st['us_per_step']:.1f} us/step (gated ±25% vs baseline).",
        ]
    cm = current.get("comms")
    if cm:
        lines += [
            "",
            f"Quantized grad-comm ({cm['mode']}): loss "
            f"{cm['int4_loss']:.4f}, gap vs fp32 collective "
            f"{cm['gap_vs_fp32_comm']:+.4f}; wire "
            f"{cm['wire_bytes']:,} B vs fp32 {cm['fp32_wire_bytes']:,} B "
            f"(**{cm['ratio_vs_fp32']:.2f}x fewer**, GPT-2-M tree).",
        ]
    sv = current.get("serving")
    if sv:
        lines += [
            "",
            f"Serving ({sv['slots']} slots x {sv['tokens_per_slot']} tokens, "
            f"drain_every={sv['drain_every']}): "
            f"{sv['engine_tok_per_sec_per_slot']:.0f} tok/s/slot, "
            f"**{sv['speedup_vs_host_sync_loop']:.1f}x** over the per-token "
            f"host-sync loop (floor 3x); q4 weights "
            f"{sv['q4_weight_bytes']:,} B vs bf16 "
            f"{sv['bf16_weight_bytes']:,} B "
            f"(**{sv['q4_ratio_vs_bf16']:.2f}x fewer**, floor 3.5x).",
        ]
    lines += [
        "",
        (
            f"**DRIFT: {len(violations)} violation(s)**"
            if violations
            else "Status: within tolerance of the tracked baseline."
        ),
    ]
    lines += [f"- {v}" for v in violations]
    with open(path, "a") as f:
        f.write("\n".join(lines) + "\n")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--steps", type=int, default=drift.DEFAULT_STEPS)
    ap.add_argument(
        "--update", action="store_true", help="rewrite the baseline file"
    )
    args = ap.parse_args()

    current = drift.production_metrics(steps=args.steps)
    print("current production metrics:")
    print(json.dumps(current, indent=2))

    # Per-run measurement file: the first point is committed to start the
    # trajectory; CI rewrites it every run and uploads it as a workflow
    # artifact.  Plain local checks leave the tracked copy alone (no
    # perpetually dirty tree); ``--update`` refreshes it with the baseline.
    if args.update or os.environ.get("GITHUB_ACTIONS"):
        os.makedirs(os.path.dirname(BENCH_OUT), exist_ok=True)
        with open(BENCH_OUT, "w") as f:
            json.dump(current, f, indent=2, sort_keys=True)
            f.write("\n")

    if args.update:
        os.makedirs(os.path.dirname(args.baseline), exist_ok=True)
        with open(args.baseline, "w") as f:
            json.dump(current, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"baseline written: {args.baseline}")
        return 0

    if not os.path.exists(args.baseline):
        print(
            f"FAIL: no baseline at {args.baseline}; create one with --update",
            file=sys.stderr,
        )
        return 1
    with open(args.baseline) as f:
        baseline = json.load(f)

    violations = drift.compare(current, baseline)
    _write_step_summary(current, baseline, violations)
    if violations:
        print("\nDRIFT DETECTED vs", args.baseline, file=sys.stderr)
        for v in violations:
            print(" -", v, file=sys.stderr)
        return 1
    print(f"\nOK: within tolerance of {args.baseline}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
