"""One benchmark per paper table/figure (Tab. 1, 2, 4, 5, 6; Fig. 3, 4/Thm 1).

All train the same small LM under identical hyperparameters, varying only the
optimizer/quantizer — the paper's ablation protocol at CPU scale.
"""

from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    BENCH_CFG,
    emit,
    stacked_leaf_update_stats,
    train_small_lm,
)
from repro.core.optimizers import (
    QuantPolicy,
    make_optimizer,
    quantized_adamw,
    state_nbytes,
)
from repro.core.optimizers.adamw import M_4BIT
from repro.core.quantizer import QuantConfig, dequantize, quantize
from repro.models import init_model

LR = 3e-3


def _v_cfg(norm: str, mapping: str, block: int = 128) -> QuantConfig:
    return QuantConfig(
        bits=4, normalization=norm, block_size=block, mapping=mapping, signed=False
    )


def tab1_second_moment_ablation() -> List[Tuple[str, float, str]]:
    """Tab. 1: second-moment quantization schemes; first moment fixed B128/DE."""
    m_pol = QuantPolicy(config=M_4BIT, threshold=0)
    grid = [
        ("B2048/DE", _v_cfg("blockwise", "de", 2048), False),
        ("B128/DE", _v_cfg("blockwise", "de", 128), False),
        ("B2048/DE-0", _v_cfg("blockwise", "de0", 2048), False),
        ("B128/DE-0", _v_cfg("blockwise", "de0", 128), False),
        ("Rank-1/DE-0", _v_cfg("rank1", "de0"), False),
        ("Rank-1/Linear", _v_cfg("rank1", "linear"), False),
        ("Rank-1/Linear+Factor", _v_cfg("rank1", "linear"), True),
    ]
    rows = []
    for name, v_cfg, factored in grid:
        opt = quantized_adamw(
            LR,
            m_policy=m_pol,
            v_policy=QuantPolicy(config=v_cfg, threshold=0, factor_2d=factored),
            name=name,
        )
        r = train_small_lm(opt, steps=60)
        rows.append((
            f"tab1/{name}",
            r["us_per_step"],
            f"final_loss={r['loss_final']:.4f} unstable={int(r['unstable'])} "
            f"max_dw={r['max_param_delta']:.2f}",
        ))
    return rows


def tab2_optimizer_comparison() -> List[Tuple[str, float, str]]:
    """Tab. 2: full-precision vs memory-efficient optimizers (the production
    partition preset rides along as the quality row for fp32-embeddings +
    4-bit-SR-body training).

    The fused rows exercise the Pallas kernel route (Tab. 4's "fused"
    operator): ``4bit-AdamW-fused`` routes eligible leaves round-to-nearest,
    ``production4bit-SR`` (kernel on by default) with in-kernel stochastic
    requantization."""
    opts = [
        ("32bit-AdamW", make_optimizer("adamw32", LR), None),
        ("Adafactor", make_optimizer("adafactor", LR, b1=0.9), None),
        ("Adafactor-b1=0", make_optimizer("adafactor", LR, b1=0.0), None),
        ("SM3", make_optimizer("sm3", LR), None),
        ("8bit-AdamW", make_optimizer("adamw8bit", LR, exclude_embeddings=True), None),
        ("4bit-AdamW", make_optimizer("adamw4bit", LR), None),
        ("4bit-AdamW-fused", make_optimizer("adamw4bit", LR, use_kernel=True), None),
        ("4bit-AdamW-fused-SR",
         make_optimizer("adamw4bit", LR, stochastic_rounding=True, use_kernel=True),
         0),
        ("4bit-Factor", make_optimizer("factor4bit", LR), None),
        ("production4bit-SR", make_optimizer("production4bit", LR), 0),
        ("32bit-Shampoo", make_optimizer("shampoo32", LR), None),
        ("4bit-Shampoo", make_optimizer("shampoo4bit", LR), None),
    ]
    rows = []
    base = None
    for name, opt, sr_seed in opts:
        r = train_small_lm(opt, steps=80, sr_seed=sr_seed)
        if name == "32bit-AdamW":
            base = r["loss_final"]
        gap = r["loss_final"] - (base if base is not None else 0.0)
        rows.append((
            f"tab2/{name}",
            r["us_per_step"],
            f"final_loss={r['loss_final']:.4f} gap_vs_fp32={gap:+.4f}",
        ))
    return rows


def _gpt2m_like_params():
    """GPT-2-Medium-shaped parameter tree (~350M params) for memory tables.

    Shapes only (ShapeDtypeStruct init through eval_shape) — no allocation.
    """
    import dataclasses

    from repro.models import LayerSpec, ModelConfig

    cfg = ModelConfig(
        name="gpt2m-like", num_layers=24, d_model=1024, num_heads=16,
        num_kv_heads=16, head_dim=64, d_ff=4096, vocab_size=50257,
        blocks=(LayerSpec("dense", 0),) * 24, gated_mlp=False,
    )
    params = jax.eval_shape(lambda: init_model(jax.random.PRNGKey(0), cfg)[0])
    return params


def tab4_memory() -> List[Tuple[str, float, str]]:
    """Tab. 4: optimizer-state memory on a GPT-2-Medium-sized model."""
    params_s = _gpt2m_like_params()
    n_params = sum(
        int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params_s)
    )
    opts = [
        ("32bit-AdamW", make_optimizer("adamw32", LR)),
        ("8bit-AdamW", make_optimizer("adamw8bit", LR)),
        ("4bit-AdamW", make_optimizer("adamw4bit", LR)),
        ("4bit-Factor", make_optimizer("factor4bit", LR)),
        ("production4bit", make_optimizer("production4bit", LR)),
        ("Adafactor-b1=0", make_optimizer("adafactor", LR, b1=0.0)),
        ("SM3", make_optimizer("sm3", LR)),
        ("32bit-Shampoo", make_optimizer("shampoo32", LR)),
        ("4bit-Shampoo", make_optimizer("shampoo4bit", LR)),
    ]
    rows = []
    base = None
    for name, opt in opts:
        state_s = jax.eval_shape(lambda o=opt: o.init(
            jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), params_s)
        ))
        nbytes = state_nbytes(state_s)
        if name == "32bit-AdamW":
            base = nbytes
        saved = (base - nbytes) / base * 100 if base else 0.0
        rows.append((
            f"tab4/{name}",
            0.0,
            f"state_bytes={nbytes} bytes_per_param={nbytes/n_params:.3f} "
            f"saved_vs_fp32={saved:.1f}%",
        ))
    return rows


def tab5_largest_trainable() -> List[Tuple[str, float, str]]:
    """Tab. 5: largest trainable model under a fixed memory budget.

    Per-param training cost: params fp32 + grads fp32 + states; 80 GB budget
    (matching the paper's A100 setting) and a 30% activation reserve."""
    budget = 80e9 * 0.7
    per_param = {
        "32bit-AdamW": 4 + 4 + 8.0,
        "8bit-AdamW": 4 + 4 + 2.0,
        "4bit-AdamW": 4 + 4 + 1.0 + 0.09,  # + scale overhead
        "4bit-Factor": 4 + 4 + 0.5 + 0.05,
    }
    rows = []
    for name, ppb in per_param.items():
        largest = budget / ppb / 1e9
        rows.append((f"tab5/{name}", 0.0, f"largest_trainable={largest:.2f}B_params"))
    return rows


def tab6_moment_ablation() -> List[Tuple[str, float, str]]:
    """Tab. 6: which moment is compressed."""
    m128 = QuantPolicy(config=M_4BIT, threshold=0)
    m2048 = QuantPolicy(
        config=QuantConfig(bits=4, normalization="blockwise", block_size=2048,
                           mapping="de", signed=True),
        threshold=0,
    )
    v_r1lin = QuantPolicy(config=_v_cfg("rank1", "linear"), threshold=0)
    grid = [
        ("none", QuantPolicy(), QuantPolicy(), False),
        ("m:B2048/DE", m2048, QuantPolicy(), False),
        ("m:B128/DE", m128, QuantPolicy(), False),
        ("m:B128/DE+v:Rank1/Lin", m128, v_r1lin, False),
        ("m:B128/DE+v:factored", m128,
         QuantPolicy(config=_v_cfg("rank1", "linear"), threshold=0, factor_2d=True),
         True),
    ]
    rows = []
    for name, m_pol, v_pol, _ in grid:
        opt = quantized_adamw(LR, m_policy=m_pol, v_policy=v_pol, name=name)
        r = train_small_lm(opt, steps=80)
        rows.append((
            f"tab6/{name}", r["us_per_step"],
            f"final_loss={r['loss_final']:.4f}",
        ))
    return rows


def fig3_zero_point() -> List[Tuple[str, float, str]]:
    """Fig. 3: histogram of h(v)=1/(sqrt(v)+1e-6) under quantizers."""
    rng = np.random.default_rng(0)
    # realistic second moment: row-structured lognormal (App. B patterns)
    rowscale = 10.0 ** rng.uniform(-6, -2, size=(256, 1))
    v = jnp.asarray(
        (rng.lognormal(0, 1.0, size=(256, 1024)) * rowscale).astype(np.float32)
    )
    h = lambda t: 1.0 / (jnp.sqrt(t) + 1e-6)
    rows = []
    for name, cfg in [
        ("B128/DE", _v_cfg("blockwise", "de")),
        ("B128/DE-0", _v_cfg("blockwise", "de0")),
        ("Rank-1/Linear", _v_cfg("rank1", "linear")),
    ]:
        vq = dequantize(quantize(v, cfg))
        collapsed = float(jnp.mean(vq == 0.0))
        err = jnp.abs(jnp.log10(h(vq)) - jnp.log10(h(v)))
        rows.append((
            f"fig3/{name}", 0.0,
            f"frac_zero={collapsed:.4f} h_log10_err_mean={float(jnp.mean(err)):.4f} "
            f"h_log10_err_p99={float(jnp.percentile(err, 99)):.4f}",
        ))
    return rows


def thm1_sgdm_convergence() -> List[Tuple[str, float, str]]:
    """Theorem 1: compressed SGDM on a convex quadratic converges to a noise
    ball whose radius grows with quantization variance."""
    rng = np.random.default_rng(1)
    dim = 8192
    target = jnp.asarray(rng.normal(size=(1, dim)).astype(np.float32))
    params = {"w": jnp.zeros((1, dim))}

    def run(opt, key=None, steps=150):
        state = opt.init(params)
        p = params
        upd = jax.jit(opt.update)
        for t in range(steps):
            g = {"w": (p["w"] - target) + 0.01 * jnp.asarray(
                np.random.default_rng(t).normal(size=(1, dim)).astype(np.float32))}
            k = jax.random.fold_in(key, t) if key is not None else None
            p, state = (upd(g, state, p, key=k) if k is not None else upd(g, state, p))
        return float(jnp.mean((p["w"] - target) ** 2))

    e32 = run(make_optimizer("sgdm", 5e-2))
    e4 = run(make_optimizer("sgdm4bit", 5e-2), key=jax.random.PRNGKey(0))
    return [
        ("thm1/sgdm32", 0.0, f"final_mse={e32:.6f}"),
        ("thm1/sgdm4bit_sr", 0.0,
         f"final_mse={e4:.6f} ratio_vs_fp32={e4/max(e32,1e-12):.2f}"),
    ]


def stacked_fused_steptime() -> List[Tuple[str, float, str]]:
    """Stacked-leaf fused update: an L=24 transformer-block stack must run as
    ONE 3-d-grid Pallas launch (the ROADMAP "fuse the stacked-leaf loop"
    item) — the row records the launch count and the SR step wall-clock."""
    s = stacked_leaf_update_stats()
    return [(
        f"stacked/L{s['L']}x{s['R']}x{s['C']}-fused-SR",
        s["us_per_step"],
        f"pallas_launches={s['launch_count']} (single 3-d-grid launch; "
        f"was {s['L']} per-slice launches)",
    )]


def grad_comm_wire() -> List[Tuple[str, float, str]]:
    """Gradient-collective bytes on the wire per train step (``repro.comms``)
    for the GPT-2-M gradient tree — structural, computed from shapes alone.

    fp32 is the baseline collective; bf16 halves it; int8/int4 move
    block-quantized codes + fp32 absmax scales (B128), with sub-threshold
    leaves (biases, norms) kept fp32 (App. D.1 policy)."""
    from repro.comms import mode_totals

    params_s = _gpt2m_like_params()
    rows = []
    for r in mode_totals(params_s):
        rows.append((
            f"comms/{r['mode']}",
            0.0,
            f"wire_bytes={r['total_wire_bytes']} "
            f"ratio_vs_fp32={r['ratio_vs_fp32']:.2f} "
            f"quantized_leaves={r['quantized_leaves']}/{r['n_leaves']}",
        ))
    return rows


def serving_throughput() -> List[Tuple[str, float, str]]:
    """Serving engine tokens/sec/slot vs the legacy per-token host-sync loop,
    plus the structural q4 weight-byte row (``benchmarks/serving.py``)."""
    from benchmarks.serving import serving_throughput as rows

    return rows()


ALL_TABLES = [
    tab1_second_moment_ablation,
    tab2_optimizer_comparison,
    tab4_memory,
    tab5_largest_trainable,
    tab6_moment_ablation,
    fig3_zero_point,
    thm1_sgdm_convergence,
    stacked_fused_steptime,
    grad_comm_wire,
    serving_throughput,
]
