"""Serving throughput benchmark: tokens/sec/slot, engine vs the legacy loop.

Two decode paths over the same tiny dense LM and the same workload
(``SLOTS`` streams × ``TOKENS`` greedy tokens each, short prompts):

* **legacy** — the pre-rewrite engine loop, reconstructed inline: one jitted
  ``decode_step`` per token with a host sync (``np.asarray(argmax)``) every
  tick and teacher-forced token-at-a-time prefill.  Its cost is dominated by
  per-token dispatch + device→host latency, which is exactly why it was
  replaced.
* **engine** — the rewritten ``ServeEngine``: one-shot batched prefill and a
  jitted ``lax.scan`` over ``drain_every`` decode steps, so the host syncs
  once per chunk.

The model is deliberately small: the benchmark measures the *loop* (dispatch
and sync overhead), not matmul throughput — that ratio is what the rewrite
changes and what the drift gate floors at 3x.  Wall-clock is the best of
``repeats`` timed runs after a compile warmup; tokens/sec/slot is recorded
for the trajectory while only the legacy/engine *ratio* is gated (absolute
CI-machine speed is too noisy to pin).

Weight-memory figures (bf16 vs q4 serving formats on the GPT-2-M tree) are
structural — exact on any platform — and gated exactly, with the q4
compression ratio floored at 3.5x.
"""

from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import (
    LayerSpec,
    ModelConfig,
    decode_step,
    init_model,
    init_serve_cache,
)
from repro.serve import Request, ServeEngine, weight_report

SERVE_BENCH_CFG = ModelConfig(
    name="serve-bench",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=256,
    vocab_size=256,
    blocks=(LayerSpec("dense", 0),) * 2,
    remat=False,
)

SLOTS = 4
TOKENS = 64
PROMPT_LEN = 4
DRAIN_EVERY = 16
S_MAX = 256


def _legacy_wall(params, cfg: ModelConfig, B: int, T: int) -> float:
    """One timed run of the pre-rewrite loop: teacher-forced prefill plus T
    greedy tokens per slot, host-syncing the argmax every tick."""
    caches = init_serve_cache(cfg, B, S_MAX)
    step = jax.jit(lambda p, c, t, q: decode_step(p, cfg, c, t, q))
    prompts = [[1 + b, 2 + b, 3 + b, 4 + b][:PROMPT_LEN] for b in range(B)]

    t0 = time.perf_counter()
    tokens = np.zeros((B,), np.int32)
    for t in range(PROMPT_LEN):  # token-at-a-time teacher forcing
        tokens = np.array([p[t] for p in prompts], np.int32)
        pos = np.full((B,), t, np.int32)
        logits, caches = step(params, caches, jnp.asarray(tokens), jnp.asarray(pos))
    tokens = np.asarray(jnp.argmax(logits, axis=-1)).astype(np.int32)
    for t in range(T - 1):  # host sync every generated token
        pos = np.full((B,), PROMPT_LEN + t, np.int32)
        logits, caches = step(params, caches, jnp.asarray(tokens), jnp.asarray(pos))
        tokens = np.asarray(jnp.argmax(logits, axis=-1)).astype(np.int32)
    return time.perf_counter() - t0


def _engine_wall(eng: ServeEngine, B: int, T: int, rid0: int) -> float:
    """One timed run of the rewritten engine on the same workload."""
    reqs = [
        Request(rid=rid0 + b, prompt=[1 + b, 2 + b, 3 + b, 4 + b][:PROMPT_LEN],
                max_new_tokens=T)
        for b in range(B)
    ]
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    eng.run()
    wall = time.perf_counter() - t0
    assert all(r.done and len(r.output) == T for r in reqs)
    return wall


def serving_stats(
    B: int = SLOTS, T: int = TOKENS, repeats: int = 3
) -> Dict[str, float]:
    """Measured throughput plus structural weight-memory figures."""
    params, _ = init_model(jax.random.PRNGKey(0), SERVE_BENCH_CFG)

    eng = ServeEngine(
        SERVE_BENCH_CFG, params, max_batch=B, s_max=S_MAX,
        drain_every=DRAIN_EVERY,
    )
    _engine_wall(eng, B, T, rid0=10_000)  # compile warmup (prefill + decode)
    engine_wall = min(_engine_wall(eng, B, T, rid0=i * B) for i in range(repeats))

    _legacy_wall(params, SERVE_BENCH_CFG, B, T)  # compile warmup
    legacy_wall = min(_legacy_wall(params, SERVE_BENCH_CFG, B, T) for _ in range(repeats))

    from benchmarks.tables import _gpt2m_like_params

    params_s = _gpt2m_like_params()
    bf16 = weight_report(params_s, "bf16")
    q4 = weight_report(params_s, "q4")

    return {
        "slots": B,
        "tokens_per_slot": T,
        "drain_every": DRAIN_EVERY,
        "engine_tok_per_sec_per_slot": round(T / engine_wall, 1),
        "legacy_tok_per_sec_per_slot": round(T / legacy_wall, 1),
        "speedup_vs_host_sync_loop": round(legacy_wall / engine_wall, 2),
        "bf16_weight_bytes": bf16["total_serve_bytes"],
        "q4_weight_bytes": q4["total_serve_bytes"],
        "q4_ratio_vs_bf16": q4["ratio_vs_bf16"],
    }


def serving_throughput() -> List[Tuple[str, float, str]]:
    """Benchmark-table rows: tokens/sec/slot for both loops + weight bytes."""
    s = serving_stats()
    us_per_tok_engine = 1e6 / s["engine_tok_per_sec_per_slot"]
    us_per_tok_legacy = 1e6 / s["legacy_tok_per_sec_per_slot"]
    return [
        (
            f"serving/engine-B{s['slots']}xT{s['tokens_per_slot']}",
            us_per_tok_engine,
            f"tok_per_sec_per_slot={s['engine_tok_per_sec_per_slot']} "
            f"drain_every={s['drain_every']} "
            f"speedup_vs_legacy={s['speedup_vs_host_sync_loop']}x",
        ),
        (
            f"serving/legacy-B{s['slots']}xT{s['tokens_per_slot']}",
            us_per_tok_legacy,
            f"tok_per_sec_per_slot={s['legacy_tok_per_sec_per_slot']} "
            "(host sync every token)",
        ),
        (
            "serving/q4-weights",
            0.0,
            f"weight_bytes={s['q4_weight_bytes']} "
            f"vs_bf16={s['q4_ratio_vs_bf16']:.2f}x fewer (GPT-2-M tree)",
        ),
    ]
