"""Tracked production-preset drift metrics (ROADMAP drift-tracking item).

``production_metrics()`` distills the benchmark suite's production rows into
a small deterministic JSON-able dict:

* quality — final loss of ``adamw32`` vs ``production4bit`` (SR seed 0, so
  the kernel-routed SR body runs with real quantization noise) on the shared
  bench LM, and their gap.  Fully deterministic on a fixed platform: data,
  init and SR stream are all seeded.
* memory — optimizer-state bytes on the GPT-2-Medium-shaped tree
  (``eval_shape`` only, no allocation) and the production/fp32 ratio.
  Structural, so it must reproduce exactly anywhere.
* stacked — the fused stacked-leaf update on an L=24 transformer-block
  stack: the Pallas launch count (structural; gated EXACTLY at its baseline
  of 1 — the single-launch 3-d-grid invariant) and the step wall-clock
  (recorded for the per-PR trajectory, not gated: CI machines are noisy).

``compare()`` checks a freshly computed dict against the tracked baseline
(``benchmarks/results/baseline.json``) within tolerances; the CI job
(``scripts_check_drift.py``) fails on violations, catching quality/memory
regressions of the production preset over time.
"""

from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import stacked_leaf_update_stats, train_small_lm
from benchmarks.tables import _gpt2m_like_params
from repro.core.optimizers import make_optimizer, state_nbytes

DEFAULT_STEPS = 80
SR_SEED = 0

# |gap drift| tolerance in nats: generous enough for BLAS/platform jitter on
# an 80-step micro-LM, tight enough to catch a real quality regression of the
# 4-bit body (which shows up as multiples of this on divergence).
LOSS_GAP_TOL = 0.08
# memory ratio is structural; anything beyond fp rounding is a layout change
MEMORY_RATIO_TOL = 1e-3


def production_metrics(steps: int = DEFAULT_STEPS) -> Dict:
    """Compute the tracked quality/memory numbers (deterministic per platform)."""
    r32 = train_small_lm(make_optimizer("adamw32", 3e-3), steps=steps)
    rprod = train_small_lm(
        make_optimizer("production4bit", 3e-3), steps=steps, sr_seed=SR_SEED
    )

    params_s = _gpt2m_like_params()
    n_params = sum(
        int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params_s)
    )

    def state_bytes(name):
        opt = make_optimizer(name, 3e-3)
        state_s = jax.eval_shape(
            lambda: opt.init(
                jax.tree_util.tree_map(
                    lambda s: jnp.zeros(s.shape, s.dtype), params_s
                )
            )
        )
        return state_nbytes(state_s)

    b32 = state_bytes("adamw32")
    bprod = state_bytes("production4bit")
    stacked = stacked_leaf_update_stats()
    return {
        "meta": {"steps": steps, "sr_seed": SR_SEED, "lr": 3e-3},
        "quality": {
            "adamw32_loss": round(r32["loss_final"], 6),
            "production4bit_loss": round(rprod["loss_final"], 6),
            "gap": round(rprod["loss_final"] - r32["loss_final"], 6),
            "production4bit_unstable": bool(rprod["unstable"]),
        },
        "memory": {
            "n_params": n_params,
            "adamw32_state_bytes": int(b32),
            "production4bit_state_bytes": int(bprod),
            "ratio": round(bprod / b32, 6),
        },
        "stacked": {
            "L": stacked["L"],
            "R": stacked["R"],
            "C": stacked["C"],
            "launch_count": stacked["launch_count"],
            "us_per_step": round(stacked["us_per_step"], 1),
        },
    }


def compare(
    current: Dict,
    baseline: Dict,
    *,
    loss_gap_tol: float = LOSS_GAP_TOL,
    memory_ratio_tol: float = MEMORY_RATIO_TOL,
) -> List[str]:
    """Return human-readable violations of ``current`` vs ``baseline``."""
    violations = []
    if current["meta"]["steps"] != baseline["meta"]["steps"]:
        violations.append(
            f"meta.steps mismatch: current {current['meta']['steps']} vs "
            f"baseline {baseline['meta']['steps']} — regenerate with matching "
            "--steps or --update the baseline"
        )
        return violations

    if current["quality"]["production4bit_unstable"]:
        violations.append("production4bit run went unstable (nonfinite/blowup)")

    gap_cur = current["quality"]["gap"]
    gap_base = baseline["quality"]["gap"]
    if abs(gap_cur - gap_base) > loss_gap_tol:
        violations.append(
            "quality gap (production4bit - adamw32 final loss) drifted: "
            f"{gap_cur:+.4f} vs baseline {gap_base:+.4f} "
            f"(tol {loss_gap_tol})"
        )

    for key in ("adamw32_state_bytes", "production4bit_state_bytes", "n_params"):
        if current["memory"][key] != baseline["memory"][key]:
            violations.append(
                f"memory.{key} changed: {current['memory'][key]} vs "
                f"baseline {baseline['memory'][key]} — state layout drift"
            )
    if abs(current["memory"]["ratio"] - baseline["memory"]["ratio"]) > memory_ratio_tol:
        violations.append(
            f"memory ratio drifted: {current['memory']['ratio']:.6f} vs "
            f"baseline {baseline['memory']['ratio']:.6f}"
        )

    # The single-launch invariant: launch count is structural and gated
    # exactly; us_per_step is trajectory-only (never a violation).  A
    # baseline without the section is tolerated (pre-gate baselines), but
    # once the baseline records it, a current run missing it means the gate
    # silently stopped executing — that is itself a violation.
    base_st = baseline.get("stacked")
    cur_st = current.get("stacked")
    if base_st and not cur_st:
        violations.append(
            "stacked metrics missing from the current run — the launch-count "
            "gate did not execute (baseline still records it)"
        )
    elif base_st and cur_st:
        for key in ("L", "R", "C", "launch_count"):
            if cur_st[key] != base_st[key]:
                violations.append(
                    f"stacked.{key} changed: {cur_st[key]} vs baseline "
                    f"{base_st[key]} — the fused stacked-leaf path regressed "
                    "(single-launch 3-d grid)"
                )
    return violations
