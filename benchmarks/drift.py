"""Tracked production-preset drift metrics (ROADMAP drift-tracking item).

``production_metrics()`` distills the benchmark suite's production rows into
a small deterministic JSON-able dict:

* quality — final loss of ``adamw32`` vs ``production4bit`` (SR seed 0, so
  the kernel-routed SR body runs with real quantization noise) on the shared
  bench LM, and their gap.  Fully deterministic on a fixed platform: data,
  init and SR stream are all seeded.
* memory — optimizer-state bytes on the GPT-2-Medium-shaped tree
  (``eval_shape`` only, no allocation) and the production/fp32 ratio.
  Structural, so it must reproduce exactly anywhere.
* stacked — the fused stacked-leaf update on an L=24 transformer-block
  stack: the Pallas launch count (structural; gated EXACTLY at its baseline
  of 1 — the single-launch 3-d-grid invariant) and the step wall-clock,
  gated within a ±25% relative band of the baseline: wide enough for CI
  machine noise, tight enough that a silent 2x slowdown (or the ~20%
  regression that once landed unnoticed) fails the job instead of merging.
* comms — the quantized-gradient-communication quality row: production4bit
  trained with the int4 gradient-collective wire format vs the fp32
  collective (same SR seed), plus the structural bytes-on-the-wire figures
  for the GPT-2-M gradient tree.  The loss gap is gated like quality; the
  wire bytes are exact and the compression ratio must stay >= 4x (the
  acceptance floor for int4 transport).
* serving — the throughput engine vs the legacy per-token host-sync loop
  on the same workload (``benchmarks/serving.py``), plus the structural
  bf16/q4 weight-byte figures for the GPT-2-M tree.  Absolute tok/s/slot
  is recorded for the trajectory only (CI machines vary); the engine/legacy
  speedup must hold the >= 3x floor and the q4 weight-compression ratio
  the >= 3.5x floor.  Weight bytes are exact.

* shampoo — the 4-bit Shampoo quality gap vs the fp32 Shampoo oracle on the
  same bench LM (gated like quality), plus the structural
  Kronecker-factor bytes on the GPT-2-M tree and their compression ratio,
  floored at >= 4x (the ISSUE 10 acceptance criterion).

``compare()`` checks a freshly computed dict against the tracked baseline
(``benchmarks/results/baseline.json``) within tolerances; the CI job
(``scripts_check_drift.py``) fails on violations, catching quality/memory
regressions of the production preset over time.
"""

from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import stacked_leaf_update_stats, train_small_lm
from benchmarks.tables import _gpt2m_like_params
from repro.comms import CommsConfig, wire_report
from repro.core.optimizers import make_optimizer, state_nbytes

DEFAULT_STEPS = 80
SR_SEED = 0

# |gap drift| tolerance in nats: generous enough for BLAS/platform jitter on
# an 80-step micro-LM, tight enough to catch a real quality regression of the
# 4-bit body (which shows up as multiples of this on divergence).
LOSS_GAP_TOL = 0.08
# memory ratio is structural; anything beyond fp rounding is a layout change
MEMORY_RATIO_TOL = 1e-3
# stacked us_per_step band: relative drift vs baseline before failing.
STEP_TIME_REL_TOL = 0.25
# int4 transport must keep at least this much compression on the wire.
COMMS_MIN_RATIO = 4.0
# chunked-decode engine must stay at least this much faster than the legacy
# per-token host-sync loop (measured ~12x on CPU; 3x is the acceptance floor).
SERVING_MIN_SPEEDUP = 3.0
# q4 serving weights must keep at least this much compression vs bf16.
SERVING_MIN_Q4_RATIO = 3.5
# 4-bit Kronecker factors must cut preconditioner bytes at least this much
# vs the fp32 Shampoo oracle (ISSUE 10 acceptance floor; structural).
SHAMPOO_MIN_FACTOR_RATIO = 4.0


def production_metrics(steps: int = DEFAULT_STEPS) -> Dict:
    """Compute the tracked quality/memory numbers (deterministic per platform)."""
    r32 = train_small_lm(make_optimizer("adamw32", 3e-3), steps=steps)
    rprod = train_small_lm(
        make_optimizer("production4bit", 3e-3), steps=steps, sr_seed=SR_SEED
    )

    params_s = _gpt2m_like_params()
    n_params = sum(
        int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params_s)
    )

    def state_bytes(name):
        opt = make_optimizer(name, 3e-3)
        state_s = jax.eval_shape(
            lambda: opt.init(
                jax.tree_util.tree_map(
                    lambda s: jnp.zeros(s.shape, s.dtype), params_s
                )
            )
        )
        return state_nbytes(state_s)

    b32 = state_bytes("adamw32")
    bprod = state_bytes("production4bit")
    stacked = stacked_leaf_update_stats()

    # Quantized gradient communication: same production preset, same SR
    # seed, only the gradient-collective wire format changes (fp32 -> int4
    # block-quantized transport).  The single-process harness applies
    # exactly the quantization numerics a mesh run pays on the wire.
    int4 = CommsConfig(mode="int4")
    rint4 = train_small_lm(
        make_optimizer("production4bit", 3e-3), steps=steps, sr_seed=SR_SEED,
        comms=int4,
    )
    wire = wire_report(params_s, int4)

    # Serving: chunked-decode engine vs the legacy host-sync loop, plus the
    # structural bf16/q4 weight bytes on the same GPT-2-M tree.
    from benchmarks.serving import serving_stats

    serving = serving_stats()

    # 4-bit Shampoo: quality gap vs the fp32 Shampoo oracle on the bench LM
    # (deterministic: seeded data/init, round-to-nearest factors), plus the
    # structural preconditioner-byte ratio on the GPT-2-M tree — the four
    # Kronecker-factor trees (stats_l/stats_r/precond_l/precond_r) only.
    rsh32 = train_small_lm(make_optimizer("shampoo32", 3e-3), steps=steps)
    rsh4 = train_small_lm(make_optimizer("shampoo4bit", 3e-3), steps=steps)

    def factor_bytes(name):
        opt = make_optimizer(name, 3e-3)
        state_s = jax.eval_shape(
            lambda: opt.init(
                jax.tree_util.tree_map(
                    lambda s: jnp.zeros(s.shape, s.dtype), params_s
                )
            )
        )
        return sum(
            state_nbytes(state_s[f])
            for f in ("stats_l", "stats_r", "precond_l", "precond_r")
        )

    fb32 = factor_bytes("shampoo32")
    fb4 = factor_bytes("shampoo4bit")
    shampoo = {
        "shampoo32_loss": round(rsh32["loss_final"], 6),
        "shampoo4bit_loss": round(rsh4["loss_final"], 6),
        "gap": round(rsh4["loss_final"] - rsh32["loss_final"], 6),
        "shampoo4bit_unstable": bool(rsh4["unstable"]),
        "fp32_factor_bytes": int(fb32),
        "q4_factor_bytes": int(fb4),
        "factor_ratio": round(fb32 / fb4, 6),
    }
    return {
        "meta": {"steps": steps, "sr_seed": SR_SEED, "lr": 3e-3},
        "quality": {
            "adamw32_loss": round(r32["loss_final"], 6),
            "production4bit_loss": round(rprod["loss_final"], 6),
            "gap": round(rprod["loss_final"] - r32["loss_final"], 6),
            "production4bit_unstable": bool(rprod["unstable"]),
        },
        "memory": {
            "n_params": n_params,
            "adamw32_state_bytes": int(b32),
            "production4bit_state_bytes": int(bprod),
            "ratio": round(bprod / b32, 6),
        },
        "stacked": {
            "L": stacked["L"],
            "R": stacked["R"],
            "C": stacked["C"],
            "launch_count": stacked["launch_count"],
            "us_per_step": round(stacked["us_per_step"], 1),
        },
        "comms": {
            "mode": int4.name,
            "int4_loss": round(rint4["loss_final"], 6),
            "gap_vs_fp32_comm": round(
                rint4["loss_final"] - rprod["loss_final"], 6
            ),
            "int4_unstable": bool(rint4["unstable"]),
            "wire_bytes": wire["total_wire_bytes"],
            "fp32_wire_bytes": wire["total_fp32_bytes"],
            "ratio_vs_fp32": wire["ratio_vs_fp32"],
        },
        "serving": serving,
        "shampoo": shampoo,
    }


def compare(
    current: Dict,
    baseline: Dict,
    *,
    loss_gap_tol: float = LOSS_GAP_TOL,
    memory_ratio_tol: float = MEMORY_RATIO_TOL,
    step_time_rel_tol: float = STEP_TIME_REL_TOL,
) -> List[str]:
    """Return human-readable violations of ``current`` vs ``baseline``."""
    violations = []
    if current["meta"]["steps"] != baseline["meta"]["steps"]:
        violations.append(
            f"meta.steps mismatch: current {current['meta']['steps']} vs "
            f"baseline {baseline['meta']['steps']} — regenerate with matching "
            "--steps or --update the baseline"
        )
        return violations

    if current["quality"]["production4bit_unstable"]:
        violations.append("production4bit run went unstable (nonfinite/blowup)")

    gap_cur = current["quality"]["gap"]
    gap_base = baseline["quality"]["gap"]
    if abs(gap_cur - gap_base) > loss_gap_tol:
        violations.append(
            "quality gap (production4bit - adamw32 final loss) drifted: "
            f"{gap_cur:+.4f} vs baseline {gap_base:+.4f} "
            f"(tol {loss_gap_tol})"
        )

    for key in ("adamw32_state_bytes", "production4bit_state_bytes", "n_params"):
        if current["memory"][key] != baseline["memory"][key]:
            violations.append(
                f"memory.{key} changed: {current['memory'][key]} vs "
                f"baseline {baseline['memory'][key]} — state layout drift"
            )
    if abs(current["memory"]["ratio"] - baseline["memory"]["ratio"]) > memory_ratio_tol:
        violations.append(
            f"memory ratio drifted: {current['memory']['ratio']:.6f} vs "
            f"baseline {baseline['memory']['ratio']:.6f}"
        )

    # The single-launch invariant: launch count is structural and gated
    # exactly; us_per_step is gated within a relative band (a ~20% L=24
    # slowdown once merged silently when the figure was trajectory-only).
    # A baseline without the section is tolerated (pre-gate baselines), but
    # once the baseline records it, a current run missing it means the gate
    # silently stopped executing — that is itself a violation.
    base_st = baseline.get("stacked")
    cur_st = current.get("stacked")
    if base_st and not cur_st:
        violations.append(
            "stacked metrics missing from the current run — the launch-count "
            "gate did not execute (baseline still records it)"
        )
    elif base_st and cur_st:
        for key in ("L", "R", "C", "launch_count"):
            if cur_st[key] != base_st[key]:
                violations.append(
                    f"stacked.{key} changed: {cur_st[key]} vs baseline "
                    f"{base_st[key]} — the fused stacked-leaf path regressed "
                    "(single-launch 3-d grid)"
                )
        base_us = base_st.get("us_per_step")
        cur_us = cur_st.get("us_per_step")
        if base_us and cur_us:
            rel = (cur_us - base_us) / base_us
            if abs(rel) > step_time_rel_tol:
                violations.append(
                    f"stacked.us_per_step drifted {rel:+.0%}: {cur_us:.1f} vs "
                    f"baseline {base_us:.1f} (band ±{step_time_rel_tol:.0%}) — "
                    "regenerate the baseline with --update if intentional"
                )

    # Quantized gradient communication: the int4-transport quality gap is
    # gated like the optimizer quality gap; wire bytes are structural
    # (exact), and the compression ratio must hold the >= 4x floor.
    base_cm = baseline.get("comms")
    cur_cm = current.get("comms")
    if base_cm and not cur_cm:
        violations.append(
            "comms metrics missing from the current run — the quantized "
            "grad-comm gate did not execute (baseline still records it)"
        )
    elif base_cm and cur_cm:
        if cur_cm["int4_unstable"]:
            violations.append(
                "production4bit + int4 grad-comm run went unstable"
            )
        if abs(cur_cm["gap_vs_fp32_comm"] - base_cm["gap_vs_fp32_comm"]) > loss_gap_tol:
            violations.append(
                "comms quality gap (int4 vs fp32 gradient collective) "
                f"drifted: {cur_cm['gap_vs_fp32_comm']:+.4f} vs baseline "
                f"{base_cm['gap_vs_fp32_comm']:+.4f} (tol {loss_gap_tol})"
            )
        for key in ("wire_bytes", "fp32_wire_bytes"):
            if cur_cm[key] != base_cm[key]:
                violations.append(
                    f"comms.{key} changed: {cur_cm[key]} vs baseline "
                    f"{base_cm[key]} — wire format drift"
                )
        if cur_cm["ratio_vs_fp32"] < COMMS_MIN_RATIO:
            violations.append(
                f"comms compression ratio {cur_cm['ratio_vs_fp32']:.2f}x fell "
                f"below the {COMMS_MIN_RATIO:.0f}x floor for int4 transport"
            )

    # Serving: absolute tok/s/slot is trajectory-only (machine-dependent);
    # the engine/legacy speedup and the q4 weight ratio are floored, and the
    # structural weight bytes are exact.
    base_sv = baseline.get("serving")
    cur_sv = current.get("serving")
    if base_sv and not cur_sv:
        violations.append(
            "serving metrics missing from the current run — the serving "
            "throughput gate did not execute (baseline still records it)"
        )
    elif base_sv and cur_sv:
        if cur_sv["speedup_vs_host_sync_loop"] < SERVING_MIN_SPEEDUP:
            violations.append(
                "serving engine speedup over the per-token host-sync loop "
                f"fell to {cur_sv['speedup_vs_host_sync_loop']:.2f}x, below "
                f"the {SERVING_MIN_SPEEDUP:.0f}x floor — chunked decode "
                "regressed (extra syncs or lost scan fusion)"
            )
        for key in ("bf16_weight_bytes", "q4_weight_bytes"):
            if cur_sv[key] != base_sv[key]:
                violations.append(
                    f"serving.{key} changed: {cur_sv[key]} vs baseline "
                    f"{base_sv[key]} — serving weight-format drift"
                )
        if cur_sv["q4_ratio_vs_bf16"] < SERVING_MIN_Q4_RATIO:
            violations.append(
                f"serving q4 weight compression {cur_sv['q4_ratio_vs_bf16']:.2f}x "
                f"fell below the {SERVING_MIN_Q4_RATIO:.1f}x floor vs bf16"
            )

    # 4-bit Shampoo: the quality gap vs the fp32 oracle is gated like the
    # production quality gap; factor bytes are structural (exact) and the
    # compression ratio must hold the >= 4x acceptance floor.
    base_sh = baseline.get("shampoo")
    cur_sh = current.get("shampoo")
    if base_sh and not cur_sh:
        violations.append(
            "shampoo metrics missing from the current run — the 4-bit "
            "Shampoo gate did not execute (baseline still records it)"
        )
    elif base_sh and cur_sh:
        if cur_sh["shampoo4bit_unstable"]:
            violations.append("shampoo4bit run went unstable (nonfinite/blowup)")
        if abs(cur_sh["gap"] - base_sh["gap"]) > loss_gap_tol:
            violations.append(
                "shampoo quality gap (shampoo4bit - shampoo32 final loss) "
                f"drifted: {cur_sh['gap']:+.4f} vs baseline "
                f"{base_sh['gap']:+.4f} (tol {loss_gap_tol})"
            )
        for key in ("fp32_factor_bytes", "q4_factor_bytes"):
            if cur_sh[key] != base_sh[key]:
                violations.append(
                    f"shampoo.{key} changed: {cur_sh[key]} vs baseline "
                    f"{base_sh[key]} — Kronecker-factor layout drift"
                )
        if cur_sh["factor_ratio"] < SHAMPOO_MIN_FACTOR_RATIO:
            violations.append(
                f"shampoo factor compression {cur_sh['factor_ratio']:.2f}x "
                f"fell below the {SHAMPOO_MIN_FACTOR_RATIO:.0f}x floor for "
                "4-bit Kronecker factors"
            )
    return violations
