"""Benchmark orchestrator. One function per paper table/figure; prints
``name,us_per_call,derived`` CSV (plus roofline summaries when the dry-run
artifacts exist)."""

from __future__ import annotations

import json
import os

from benchmarks.common import emit
from benchmarks.tables import ALL_TABLES


def roofline_rows():
    rows = []
    for path, tag in (("results/dryrun.json", "dryrun"),
                      ("results/roofline.json", "roofline")):
        if not os.path.exists(path):
            continue
        for r in json.load(open(path)):
            if r.get("status") != "ok" or "roofline" not in r:
                continue
            if r.get("mesh", "single") != "single":
                continue
            t = r["roofline"]
            dom = max(
                ("compute", "memory", "collective"),
                key=lambda k: t[f"{k}_s"],
            )
            rows.append((
                f"{tag}/{r['arch']}/{r['shape']}",
                0.0,
                f"compute_s={t['compute_s']:.4g} memory_s={t['memory_s']:.4g} "
                f"collective_s={t['collective_s']:.4g} bottleneck={dom} "
                f"useful_ratio={t['useful_ratio']:.3f}",
            ))
    return rows


def main() -> None:
    print("name,us_per_call,derived")
    for fn in ALL_TABLES:
        emit(fn())
    emit(roofline_rows())


if __name__ == "__main__":
    main()
