"""Shared benchmark harness: a small LM trained on the synthetic pipeline.

Every table benchmark trains the same ~6M-param transformer under identical
hyperparameters and varies only the optimizer/quantizer — the paper's
protocol ("out-of-box transfer from full-precision optimizer to low-bit
optimizer without extra hyperparameter tuning").
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import LayerSpec, ModelConfig, init_model, loss_fn
from repro.train.train_loop import build_train_step, make_train_state

# d_ff is a multiple of 256 so the mlp w1/w3 leaves (2, 128, 512) satisfy the
# fused-kernel layout contract — the tab2 fused rows and the production preset
# actually exercise the Pallas route instead of silently falling back.
BENCH_CFG = ModelConfig(
    name="bench-lm",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=512,
    vocab_size=512,
    blocks=(LayerSpec("dense", 0),) * 2,
    remat=False,
)

DATA_CFG = DataConfig(vocab_size=512, seq_len=64, global_batch=16, seed=0)


def train_small_lm(optimizer, steps: int = 150, cfg: ModelConfig = BENCH_CFG,
                   seed: int = 0, sr_seed: int = None,
                   comms=None) -> Dict[str, float]:
    """Train the benchmark LM; returns summary metrics.

    ``sr_seed`` threads a stochastic-rounding PRNG key through the train
    step (needed for SR optimizers to actually round stochastically).
    ``comms`` (a ``repro.comms.CommsConfig``) selects the gradient-collective
    wire format; on this single-process harness quantized modes apply
    exactly the transport-quantization numerics a mesh run pays."""
    params, _ = init_model(jax.random.PRNGKey(seed), cfg)
    p0 = jax.tree_util.tree_map(lambda x: np.asarray(x), params)
    key = jax.random.PRNGKey(sr_seed) if sr_seed is not None else None
    state = make_train_state(params, optimizer, key=key)
    step_fn = jax.jit(build_train_step(cfg, optimizer, comms=comms))
    data = SyntheticLM(DATA_CFG)

    losses: List[float] = []
    t0 = time.perf_counter()
    for t in range(steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(t).items()}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
    wall = time.perf_counter() - t0

    max_delta = max(
        float(np.max(np.abs(np.asarray(b).astype(np.float32) - a.astype(np.float32))))
        for a, b in zip(
            jax.tree_util.tree_leaves(p0), jax.tree_util.tree_leaves(state.params)
        )
    )
    window = max(1, len(losses) // 10)
    return {
        "loss_first": float(np.mean(losses[:window])),
        "loss_final": float(np.mean(losses[-window:])),
        "unstable": float(not np.isfinite(losses).all() or max_delta > 50.0),
        "max_param_delta": max_delta,
        "us_per_step": wall / steps * 1e6,
    }


def emit(rows: List[Tuple[str, float, str]]):
    """Print the required ``name,us_per_call,derived`` CSV rows."""
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


def stacked_leaf_update_stats(
    L: int = 24, R: int = 128, C: int = 512, steps: int = 20
) -> Dict[str, float]:
    """Fused stacked-leaf step metrics for an (L, R, C) transformer-block
    stack — e.g. L=24 is a 24-layer stack of d_model=128 / d_ff=512 blocks.

    Returns the Pallas launch count (structural: traced under the interpret
    kernel backend, so it is the same figure a TPU run would launch) and the
    wall-clock of the jitted leaf update on the default backend (``ref`` on
    CPU — same trace shape, honest step timing).  The launch count is the
    drift-gated number: it must stay 1 (the single-launch 3-d-grid
    invariant); wall-clock is recorded for the trajectory but not gated
    (CI machines are too noisy for exact step-time equality).
    """
    from repro.core.optimizers.adamw import M_4BIT, V_4BIT
    from repro.core.quantizer import quantize
    from repro.kernels import ops as kernel_ops

    rng = np.random.default_rng(0)
    m_cfg = dataclasses.replace(M_4BIT, stochastic_rounding=True)
    v_cfg = dataclasses.replace(V_4BIT, stochastic_rounding=True)
    p = jnp.asarray(rng.normal(size=(L, R, C)).astype(np.float32) * 0.1)
    g = jnp.asarray(rng.normal(size=(L, R, C)).astype(np.float32) * 0.01)
    m_q = quantize(
        jnp.asarray(rng.normal(size=(L, R, C)).astype(np.float32) * 0.01), m_cfg
    )
    v_q = quantize(
        jnp.abs(jnp.asarray(rng.normal(size=(L, R, C)).astype(np.float32)))
        * 1e-3
        + 1e-10,
        v_cfg,
    )
    lr, bc1, bc2 = jnp.float32(3e-3), jnp.float32(0.1), jnp.float32(0.001)

    def step(p, g, m_q, v_q, key):
        return kernel_ops.fused_adamw4_leaf(
            p, g, m_q, v_q, lr, 0.9, 0.999, 1e-8, 0.01, bc1, bc2, key=key
        )

    key = jax.random.PRNGKey(0)

    # Launch count: trace with the kernel routed (interpret backend) — the
    # number of pallas_call equations is what a compiled TPU step launches.
    saved = os.environ.get("REPRO_KERNEL_BACKEND")
    os.environ["REPRO_KERNEL_BACKEND"] = "interpret"
    try:
        jaxpr = jax.make_jaxpr(step)(p, g, m_q, v_q, key)
        launches = kernel_ops.count_pallas_calls(jaxpr)
    finally:
        if saved is None:
            del os.environ["REPRO_KERNEL_BACKEND"]
        else:
            os.environ["REPRO_KERNEL_BACKEND"] = saved

    fn = jax.jit(step)
    jax.block_until_ready(fn(p, g, m_q, v_q, key))  # compile
    t0 = time.perf_counter()
    for _ in range(steps):
        out = fn(p, g, m_q, v_q, key)
    jax.block_until_ready(out)
    wall = time.perf_counter() - t0
    return {
        "L": L,
        "R": R,
        "C": C,
        "launch_count": int(launches),
        "us_per_step": wall / steps * 1e6,
    }
