"""chatglm3-6b [arXiv:2406.12793].

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024; 2d RoPE (rotary on
the first half of head_dim), GQA with 2 kv groups.
"""

from repro.models import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b",
        num_layers=28,
        d_model=4096,
        num_heads=32,
        num_kv_heads=2,
        head_dim=128,
        d_ff=13696,
        vocab_size=65024,
        rope_variant="rope2d",
        blocks=(LayerSpec("dense", 0),) * 28,
    )
