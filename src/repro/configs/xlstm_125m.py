"""xlstm-125m [arXiv:2405.04517].

12L d_model=768, 4 heads, vocab=50304, d_ff=0 (projections live inside the
xLSTM blocks). Pattern: three mLSTM blocks then one sLSTM block, repeated
(period-4 scan unit). Constant-size recurrent state => long_500k eligible.
"""

from repro.models import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m",
        num_layers=12,
        d_model=768,
        num_heads=4,
        num_kv_heads=4,
        head_dim=192,
        d_ff=0,
        vocab_size=50304,
        blocks=(
            LayerSpec("mlstm", 0), LayerSpec("mlstm", 0),
            LayerSpec("mlstm", 0), LayerSpec("slstm", 0),
        ) * 3,
    )
