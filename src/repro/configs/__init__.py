"""Architecture registry + assigned input shapes + reduced smoke configs."""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

from repro.models import LayerSpec, ModelConfig

from repro.configs import (
    chatglm3_6b,
    gemma2_2b,
    hymba_1_5b,
    internlm2_1_8b,
    mixtral_8x7b,
    phi35_moe,
    qwen2_vl_2b,
    qwen3_4b,
    whisper_large_v3,
    xlstm_125m,
)

ARCHS = {
    "phi3.5-moe-42b-a6.6b": phi35_moe.config,
    "mixtral-8x7b": mixtral_8x7b.config,
    "chatglm3-6b": chatglm3_6b.config,
    "gemma2-2b": gemma2_2b.config,
    "qwen3-4b": qwen3_4b.config,
    "internlm2-1.8b": internlm2_1_8b.config,
    "whisper-large-v3": whisper_large_v3.config,
    "xlstm-125m": xlstm_125m.config,
    "qwen2-vl-2b": qwen2_vl_2b.config,
    "hymba-1.5b": hymba_1_5b.config,
}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# long_500k requires sub-quadratic / bounded decode state (DESIGN.md §4).
LONG_CONTEXT_ARCHS = ("mixtral-8x7b", "xlstm-125m", "hymba-1.5b")


def get_config(name: str) -> ModelConfig:
    return ARCHS[name]()


def cell_is_runnable(arch: str, shape: str) -> Tuple[bool, str]:
    """(runnable, reason-if-skipped) for an (arch, shape) dry-run cell."""
    if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return False, "pure full-attention arch: 500k decode state unbounded"
    return True, ""


def reduced_config(name: str) -> ModelConfig:
    """Small same-family config for CPU smoke tests: shrunk layers/width/
    experts/vocab, same block structure and feature flags."""
    cfg = get_config(name)
    L = min(cfg.num_layers, 4)
    # preserve the pattern flavor over the first L layers
    blocks = tuple(
        LayerSpec(b.kind, min(b.window, 16) if b.window else 0)
        for b in cfg.blocks[:L]
    )
    enc_blocks = tuple(
        LayerSpec(b.kind, 0) for b in cfg.encoder_blocks[: min(len(cfg.encoder_blocks), 2)]
    )
    heads = min(cfg.num_heads, 4)
    kv = max(1, min(cfg.num_kv_heads, heads))
    while heads % kv:
        kv -= 1
    return dataclasses.replace(
        cfg,
        num_layers=L,
        blocks=blocks,
        encoder_blocks=enc_blocks,
        d_model=64,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=16,
        # d_ff is a multiple of 256 so the reduced mlp leaves satisfy the
        # fused-kernel layout contract — CPU smoke runs of production4bit /
        # use_kernel=true exercise the real kernel route, not a fallback.
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        num_experts=min(cfg.num_experts, 4),
        ssm_state=min(cfg.ssm_state, 8),
        gla_chunk=16,
        moe_group_size=64,
        mrope_sections=(4, 2, 2),
        remat=False,
    )
