"""hymba-1.5b [arXiv:2411.13676].

32L d_model=1600 25H (GQA kv=5, head_dim 64) d_ff=5504, ssm_state=16,
vocab=32001. Every block runs attention and Mamba/SSD heads in PARALLEL and
averages the (rescaled) outputs; layers 0, 15, 31 use global attention, the
rest sliding-window 1024 (aperiodic layout => run-grouped scan units).
Hymba's meta tokens are omitted (noted in DESIGN.md §8).
"""

from repro.models import LayerSpec, ModelConfig

WINDOW = 1024
GLOBAL_LAYERS = (0, 15, 31)


def config() -> ModelConfig:
    blocks = tuple(
        LayerSpec("hymba", 0 if i in GLOBAL_LAYERS else WINDOW)
        for i in range(32)
    )
    return ModelConfig(
        name="hymba-1.5b",
        num_layers=32,
        d_model=1600,
        num_heads=25,
        num_kv_heads=5,
        head_dim=64,
        d_ff=5504,
        vocab_size=32001,
        ssm_state=16,
        blocks=blocks,
    )
