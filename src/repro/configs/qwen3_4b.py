"""qwen3-4b [hf:Qwen/Qwen3-4B].

36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936; per-head q/k RMS
normalization (qk_norm), head_dim=128 (projection wider than d_model).
"""

from repro.models import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b",
        num_layers=36,
        d_model=2560,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=9728,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1e6,
        blocks=(LayerSpec("dense", 0),) * 36,
    )
