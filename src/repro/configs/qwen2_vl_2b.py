"""qwen2-vl-2b [arXiv:2409.12191].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936; M-RoPE with
(t, h, w) sections (16, 24, 24) over head_dim=128; dynamic-resolution vision
frontend is a STUB — input_specs() provides patch embeddings (B, S, d_model)
plus 3-stream position ids. Tied embeddings.
"""

from repro.models import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b",
        num_layers=28,
        d_model=1536,
        num_heads=12,
        num_kv_heads=2,
        head_dim=128,
        d_ff=8960,
        vocab_size=151936,
        rope_variant="mrope",
        mrope_sections=(16, 24, 24),
        input_mode="embeds",
        tie_embeddings=True,
        blocks=(LayerSpec("dense", 0),) * 28,
    )
