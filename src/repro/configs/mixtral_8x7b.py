"""mixtral-8x7b [arXiv:2401.04088].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, MoE 8 experts top-2,
sliding-window attention (window 4096) — which is what bounds the decode
cache and qualifies mixtral for long_500k.
"""

from repro.models import LayerSpec, ModelConfig

WINDOW = 4096


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=32000,
        num_experts=8,
        top_k=2,
        rope_theta=1e6,
        blocks=(LayerSpec("moe", WINDOW),) * 32,
    )
