"""internlm2-1.8b [arXiv:2403.17297].

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92544.
"""

from repro.models import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-1.8b",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=92544,
        blocks=(LayerSpec("dense", 0),) * 24,
    )
