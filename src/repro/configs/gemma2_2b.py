"""gemma2-2b [arXiv:2408.00118].

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000; alternating
local(4096)/global attention, attention-logit softcap 50, final-logit softcap
30, sandwich (pre+post) norms, tied embeddings, GeGLU. head_dim=256.
"""

from repro.models import LayerSpec, ModelConfig

WINDOW = 4096


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b",
        num_layers=26,
        d_model=2304,
        num_heads=8,
        num_kv_heads=4,
        head_dim=256,
        d_ff=9216,
        vocab_size=256000,
        attn_softcap=50.0,
        final_softcap=30.0,
        sandwich_norm=True,
        tie_embeddings=True,
        act="gelu",
        blocks=(LayerSpec("dense", WINDOW), LayerSpec("dense", 0)) * 13,
    )
