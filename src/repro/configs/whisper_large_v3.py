"""whisper-large-v3 [arXiv:2212.04356].

Encoder-decoder transformer backbone: 32 encoder + 32 decoder layers,
d_model=1280 20H d_ff=5120 vocab=51866, LayerNorm + GELU, sinusoidal
positions. The conv audio frontend is a STUB — input_specs() provides
precomputed frame embeddings (B, frames, d_model), per the assignment.
Shape convention for enc-dec: seq_len splits evenly into encoder frames and
decoder tokens (documented in EXPERIMENTS.md).
"""

from repro.models import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3",
        num_layers=32,
        d_model=1280,
        num_heads=20,
        num_kv_heads=20,
        head_dim=64,
        d_ff=5120,
        vocab_size=51866,
        family="encdec",
        norm_type="layernorm",
        rope_variant="none",
        act="gelu",
        gated_mlp=False,
        tie_embeddings=True,
        blocks=(LayerSpec("dec", 0),) * 32,
        encoder_blocks=(LayerSpec("enc", 0),) * 32,
    )
