"""Sharding trees for params, optimizer states, batches, and caches.

The optimizer-state walker mirrors the param tree: each param leaf maps to a
state leaf that may be a raw array (same spec + ZeRO), a QuantizedTensor
(codes shaped like the param with a halved last dim -> param spec + ZeRO;
scales replicated or ZeRO-sharded when large), or a FactoredMoment (small —
replicated).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.optimizers.base import FactoredMoment
from repro.core.quantizer import QuantizedTensor
from repro.sharding.rules import dp_axes, dp_size, spec_for, with_zero

__all__ = [
    "param_shardings",
    "opt_state_shardings",
    "batch_shardings",
    "cache_shardings",
    "replicated",
]

_IS_AXES_LEAF = lambda a: isinstance(a, tuple) and all(isinstance(s, str) for s in a)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def param_shardings(params, axes, mesh: Mesh, zero: bool = False):
    """Tree of NamedSharding matching ``params``. ``zero=True`` additionally
    shards each tensor's largest free dim over pod×data (ZeRO-3-style master
    sharding: fp32 masters never exist replicated; compute all-gathers bf16
    casts on demand)."""

    def one(p, a):
        spec = spec_for(tuple(p.shape), a, mesh)
        if zero:
            spec = with_zero(tuple(p.shape), spec, mesh, axes=a)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map(one, params, axes, is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, dict))


def _zero_spec(shape: Tuple[int, ...], base: P, mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, with_zero(shape, base, mesh))


def _sanitize_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop axis assignments whose dim is no longer divisible (e.g. packed
    4-bit codes halve the last dim: a 16-expert 'model' shard of dim 16
    becomes dim 8)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for d, e in enumerate(entries):
        if e is None:
            continue
        names = e if isinstance(e, tuple) else (e,)
        k = 1
        for n in names:
            k *= sizes[n]
        if shape[d] % k:
            entries[d] = None
    return P(*entries)


def _state_leaf_shardings(param, axes, leaf, mesh: Mesh, zero: bool):
    """Sharding subtree for one optimizer-state leaf.

    A leaf whose logical shape matches the param's is a *moment* and follows
    the param's TP spec (+ ZeRO).  A leaf with a different shape is a
    *matrix-factor* stack (Shampoo's blocked Kronecker statistics /
    preconditioners, shape ``(nblocks, B, B)``): it has no TP layout of its
    own, so it carries no base spec and is ZeRO-sharded over its largest
    divisible dim — factor state is by far the heaviest part of a Shampoo
    tree, and leaving it replicated would forfeit the ZeRO win exactly where
    it matters most.  Empty placeholders (vector params' ``(0,)`` factor
    slots) stay replicated.
    """
    p_spec = spec_for(tuple(param.shape), axes, mesh)
    mirrors = tuple(getattr(leaf, "shape", ())) == tuple(param.shape)

    if isinstance(leaf, QuantizedTensor):
        codes_shape = tuple(leaf.codes.shape)
        codes_spec = _sanitize_spec(p_spec if mirrors else P(), codes_shape, mesh)
        if zero:
            codes = _zero_spec(codes_shape, codes_spec, mesh)
        else:
            codes = NamedSharding(mesh, codes_spec)
        scale_shardings = []
        for s in leaf.scales:
            if zero and s.size >= 1 << 16 and s.ndim == 1 and s.shape[0] % dp_size(mesh) == 0:
                scale_shardings.append(_zero_spec(tuple(s.shape), P(), mesh))
            else:
                scale_shardings.append(replicated(mesh))
        return QuantizedTensor(codes, tuple(scale_shardings), leaf.shape, leaf.config)
    if isinstance(leaf, FactoredMoment):
        return FactoredMoment(replicated(mesh), replicated(mesh), leaf.shape)
    if not mirrors and (leaf.size == 0 or not zero):
        return replicated(mesh)
    # raw fp32 moment (param spec + ZeRO) or factor stack (ZeRO only)
    if zero:
        return _zero_spec(tuple(leaf.shape), p_spec if mirrors else P(), mesh)
    return NamedSharding(mesh, p_spec)


def opt_state_shardings(opt_state, params, axes, mesh: Mesh, zero: bool = True):
    """Shardings mirroring any optimizer-state pytree.

    Works structurally rather than by fixed dict keys so it covers both the
    legacy ``{'m':…, 'v':…, 'step':…}`` layout and transform-chain states
    (``ChainState`` / ``CompressedState`` / per-rule NamedTuples): any
    subtree that *mirrors the param tree* (one state leaf per param leaf with
    matching logical shape — raw array, ``QuantizedTensor``, or
    ``FactoredMoment``) is sharded like the params (+ ZeRO); step counters
    and other scalars are replicated; unrecognized leaves fall back to
    replicated.
    """
    from repro.core.optimizers.transform import ChainState, MaskedNode, PartitionState

    treedef = jax.tree_util.tree_structure(params)
    p_leaves = jax.tree_util.tree_leaves(params)
    a_leaves = jax.tree_util.tree_leaves(axes, is_leaf=_IS_AXES_LEAF)

    def _mirror_leaves(sub):
        """State subtrees at param-leaf positions, or None if not a mirror.

        ``MaskedNode`` leaves (partitioned states: positions owned by another
        partition) count as mirroring — they flatten to nothing, so the
        sharding tree just carries a matching ``MaskedNode`` placeholder.
        Leaf shapes need NOT match the param's: a mismatched array (or
        ``QuantizedTensor``) at a param position is a matrix-factor leaf
        (Shampoo Kronecker blocks) and gets factor sharding in
        ``_state_leaf_shardings``.
        """
        try:
            s_leaves = treedef.flatten_up_to(sub)
        except (ValueError, TypeError, KeyError):
            return None
        if len(s_leaves) != len(p_leaves):
            return None
        for s in s_leaves:
            if isinstance(s, (MaskedNode, QuantizedTensor, FactoredMoment)):
                continue
            if hasattr(s, "shape") and not isinstance(s, (dict, list, tuple)):
                continue
            return None
        return s_leaves

    def walk(sub):
        if sub is None:
            return None
        s_leaves = _mirror_leaves(sub)
        if s_leaves is not None:
            return jax.tree_util.tree_unflatten(
                treedef,
                [
                    s
                    if isinstance(s, MaskedNode)
                    else _state_leaf_shardings(p, a, s, mesh, zero)
                    for p, a, s in zip(p_leaves, a_leaves, s_leaves)
                ],
            )
        if isinstance(sub, ChainState):
            return ChainState(walk(s) for s in sub.states)
        if isinstance(sub, PartitionState):
            return PartitionState(
                {lab: walk(s) for lab, s in sub.states.items()}, sub.param_paths
            )
        if isinstance(sub, tuple) and hasattr(sub, "_fields"):  # NamedTuple state
            return type(sub)(*(walk(v) for v in sub))
        if isinstance(sub, dict):
            return {k: walk(v) for k, v in sub.items()}
        if isinstance(sub, (tuple, list)):
            return type(sub)(walk(v) for v in sub)
        if isinstance(sub, QuantizedTensor):  # outside a mirror: stay replicated
            return QuantizedTensor(
                replicated(mesh),
                tuple(replicated(mesh) for _ in sub.scales),
                sub.shape,
                sub.config,
            )
        if isinstance(sub, FactoredMoment):
            return FactoredMoment(replicated(mesh), replicated(mesh), sub.shape)
        return replicated(mesh)  # step counters and other scalars/arrays

    return walk(opt_state)


def batch_shardings(batch, mesh: Mesh):
    """Shard the leading (batch) dim over pod×data when divisible."""
    dps = dp_axes(mesh)
    n_dp = dp_size(mesh)
    dp_entry = dps if len(dps) > 1 else (dps[0] if dps else None)

    def one(x):
        if x.ndim == 0:
            return replicated(mesh)
        # mrope positions are (3, B, S): batch on dim 1
        batch_dim = 1 if (x.ndim >= 2 and x.shape[0] == 3 and x.shape[1] != 3) else 0
        if x.shape[batch_dim] % n_dp == 0 and n_dp > 1:
            entries = [None] * x.ndim
            entries[batch_dim] = dp_entry
            return NamedSharding(mesh, P(*entries))
        return replicated(mesh)

    return jax.tree_util.tree_map(one, batch)


def cache_shardings(caches, mesh: Mesh):
    """Decode caches: batch over dp AND cache slots over 'model'.

    Slot sharding is split-K (flash-decoding) STORAGE: a 32k x batch-128 KV
    cache is 26-40 GB/device when only batch-sharded; slots over the 16-way
    model axis cut it 16x. Attention reads gather one slot-chunk at a time
    (transient), so HBM residency stays sharded. When batch does not divide
    dp (long_500k batch=1), slots shard over 'data' as well (sequence
    parallelism)."""
    dps = dp_axes(mesh)
    n_dp = dp_size(mesh)
    dp_entry = dps if len(dps) > 1 else (dps[0] if dps else None)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(x):
        entries = [None] * x.ndim
        used_batch = False
        # stacked cache leaves: (repeat, B, slots?, ...) for KV / GLA states
        if x.ndim >= 2 and n_dp > 1 and x.shape[1] % n_dp == 0:
            entries[1] = dp_entry
            used_batch = True
        if x.ndim >= 4 and "model" in sizes:
            # dim 2 is the slots dim of stacked KV caches (rank >= 4)
            if x.shape[2] % sizes["model"] == 0 and x.shape[2] >= 256:
                entries[2] = "model"
                if not used_batch and "data" in sizes and x.shape[2] % (
                    sizes["model"] * sizes["data"]
                ) == 0:
                    entries[2] = ("data", "model")
        if any(e is not None for e in entries):
            return NamedSharding(mesh, P(*entries))
        return replicated(mesh)

    return jax.tree_util.tree_map(one, caches)
