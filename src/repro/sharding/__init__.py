"""Sharding: logical-axis rules engine + state/batch/cache sharding trees."""

from repro.sharding.rules import (
    TP_RULES,
    dp_axes,
    sharding_for,
    spec_for,
    wire_spec,
    with_zero,
)
from repro.sharding.specs import (
    batch_shardings,
    cache_shardings,
    opt_state_shardings,
    param_shardings,
    replicated,
)

__all__ = [
    "TP_RULES",
    "spec_for",
    "with_zero",
    "wire_spec",
    "sharding_for",
    "dp_axes",
    "param_shardings",
    "opt_state_shardings",
    "batch_shardings",
    "cache_shardings",
    "replicated",
]
