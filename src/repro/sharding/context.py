"""Sharding context: lets the model apply per-layer sharding constraints
inside scan bodies without coupling model code to a mesh.

Why this exists: gradients of scanned (stacked) parameters are accumulated in
the backward while-loop carry. GSPMD does not reliably propagate an
*after-the-fact* output constraint into that carry, so without an in-body
constraint the accumulator materializes replicated — for mixtral that is a
~188 GB fp32 buffer per device. Constraining the *sliced forward params*
inside the body transposes (VJP of with_sharding_constraint is
with_sharding_constraint) onto the grad slices, keeping the accumulator in
the ZeRO layout.
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding.rules import dp_axes, dp_size, spec_for, with_zero

__all__ = ["sharding_ctx", "ctx_axes", "constrain_layer_params", "constrain_activation"]

_CTX: contextvars.ContextVar[Optional[dict]] = contextvars.ContextVar(
    "repro_sharding_ctx", default=None
)

_IS_AXES_LEAF = lambda a: isinstance(a, tuple) and all(isinstance(s, str) for s in a)


@contextlib.contextmanager
def sharding_ctx(mesh: Mesh, axes=None, *, zero: bool = True):
    """``axes`` is the full model axes tree (from init_model); the model pulls
    per-unit sub-axes out of it when applying in-body constraints."""
    token = _CTX.set({"mesh": mesh, "zero": zero, "axes": axes})
    try:
        yield
    finally:
        _CTX.reset(token)


def ctx_axes(section: str):
    """Axes list for 'decoder'/'encoder' units, or None if no context."""
    ctx = _CTX.get()
    if ctx is None or ctx.get("axes") is None:
        return None
    return ctx["axes"].get(section)


def constrain_layer_params(p_sub, axes_sub):
    """Constrain one layer's (sliced) params to their TP(+ZeRO) layout.
    axes_sub leaves still carry the leading 'layers' name — dropped here."""
    ctx = _CTX.get()
    if ctx is None:
        return p_sub
    mesh, zero = ctx["mesh"], ctx["zero"]

    def one(x, a):
        a = a[1:] if (len(a) == x.ndim + 1 and a[0] == "layers") else a
        if len(a) != x.ndim:
            return x
        spec = spec_for(tuple(x.shape), a, mesh)
        if zero:
            spec = with_zero(tuple(x.shape), spec, mesh, axes=a)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(one, p_sub, axes_sub, is_leaf=_IS_AXES_LEAF)


def constrain_activation(x):
    """Constrain a (B, S, D) activation to batch-over-dp."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh = ctx["mesh"]
    n_dp = dp_size(mesh)
    if x.ndim < 2 or n_dp <= 1 or x.shape[0] % n_dp:
        return x
    dps = dp_axes(mesh)
    entry = dps if len(dps) > 1 else dps[0]
    spec = P(*([entry] + [None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
