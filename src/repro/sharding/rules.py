"""Logical-axis -> mesh-axis sharding rules with divisibility fallbacks.

Every parameter carries a tuple of logical dim names (built by the model
inits). The rules engine walks an ordered candidate list and assigns each
mesh axis to at most one tensor dim, skipping non-divisible dims — that is
what absorbs the awkward arch geometries (mixtral E=8 on a 16-way model axis
falls through to d_ff TP; hymba's 25 heads fall through to row-parallel
embed; whisper's 20 heads likewise).

ZeRO: optimizer-state leaves additionally shard their largest still-
replicated dim over the data axes (pod×data on the multi-pod mesh).
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "TP_RULES",
    "dp_axes",
    "spec_for",
    "sharding_for",
    "with_zero",
    "wire_spec",
    "mesh_axis_sizes",
]

# Ordered tensor-parallel candidates: (logical axis, mesh axis).
TP_RULES: Tuple[Tuple[str, str], ...] = (
    ("experts", "model"),
    ("heads", "model"),
    ("kv_heads", "model"),
    ("mlp", "model"),
    ("vocab", "model"),
    ("state", "model"),
    ("embed", "model"),  # last resort: row-parallel (contracting-dim shard)
)

# Logical axes that must never be sharded (scan/layer dims, tiny dims).
NEVER_SHARD = ("layers", "head_dim", "gates")


def mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Data-parallel mesh axes, outermost first (('pod','data') multi-pod)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh: Mesh) -> int:
    sizes = mesh_axis_sizes(mesh)
    n = 1
    for a in dp_axes(mesh):
        n *= sizes[a]
    return n


def spec_for(
    shape: Tuple[int, ...],
    axes: Tuple[str, ...],
    mesh: Mesh,
    rules: Sequence[Tuple[str, str]] = TP_RULES,
) -> P:
    """Tensor-parallel PartitionSpec for a parameter."""
    assert len(shape) == len(axes), (shape, axes)
    sizes = mesh_axis_sizes(mesh)
    assignment: Dict[int, str] = {}
    used_mesh = set()
    for logical, mesh_axis in rules:
        if mesh_axis in used_mesh or mesh_axis not in sizes:
            continue
        for dim, name in enumerate(axes):
            if name != logical or dim in assignment or name in NEVER_SHARD:
                continue
            if shape[dim] % sizes[mesh_axis] == 0:
                assignment[dim] = mesh_axis
                used_mesh.add(mesh_axis)
                break
    return P(*(assignment.get(d) for d in range(len(shape))))


def with_zero(shape: Tuple[int, ...], spec: P, mesh: Mesh, axes=None) -> P:
    """Add the data axes over the largest still-unsharded divisible dim
    (ZeRO state sharding). Dims named in NEVER_SHARD (e.g. the scan 'layers'
    dim) are skipped when ``axes`` is given."""
    dps = dp_axes(mesh)
    if not dps:
        return spec
    n_dp = dp_size(mesh)
    entries = list(spec) + [None] * (len(shape) - len(spec))
    order = sorted(range(len(shape)), key=lambda d: -shape[d])
    for d in order:
        if axes is not None and d < len(axes) and axes[d] in NEVER_SHARD:
            continue
        if entries[d] is None and shape[d] % n_dp == 0 and shape[d] > 0:
            entries[d] = dps if len(dps) > 1 else dps[0]
            return P(*entries)
    return P(*entries)


def wire_spec(shape: Tuple[int, ...], axes: Tuple[str, ...], mesh: Mesh) -> P:
    """ZeRO wire layout for a gradient-shaped tensor moving through the
    collective.  Also used by ``repro.comms`` for quantized transport: packed
    int4 codes keep the parameter's ndim (nibble packing halves only the last
    dim), so the same logical axes resolve their layout — the divisibility
    fallbacks in ``spec_for``/``with_zero`` absorb the halved dim exactly the
    way they absorb awkward arch geometries."""
    return with_zero(shape, spec_for(shape, axes, mesh), mesh, axes=axes)


def sharding_for(
    shape: Tuple[int, ...],
    axes: Tuple[str, ...],
    mesh: Mesh,
    zero: bool = False,
) -> NamedSharding:
    spec = spec_for(shape, axes, mesh)
    if zero:
        spec = with_zero(shape, spec, mesh)
    return NamedSharding(mesh, spec)
