"""Bytes-on-the-wire accounting for the gradient collective.

Everything here is structural — computed from leaf shapes alone (arrays and
``ShapeDtypeStruct`` trees both work, no allocation) — so the numbers are
exact, platform-independent, and cheap enough to gate in CI: per-step
collective bytes per leaf and in total, fp32 baseline vs the configured
wire format.  Surfaced in ``benchmarks/tables.py`` (``comms/*`` rows), the
drift gate (``benchmarks/drift.py``) and the CI step summary
(``scripts_comms_report.py``).
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax

from repro.comms.config import GRAD_COMM_MODES, CommsConfig
from repro.core.optimizers.base import tree_paths
from repro.core.quantizer import quantized_nbytes

__all__ = [
    "leaf_wire_bytes",
    "wire_report",
    "mode_totals",
    "format_wire_table",
]


def _numel(shape: Tuple[int, ...]) -> int:
    n = 1
    for d in shape:
        n *= int(d)
    return n


def leaf_wire_bytes(shape: Tuple[int, ...], config: CommsConfig) -> Tuple[int, int]:
    """``(fp32_bytes, wire_bytes)`` for one gradient leaf per reduction.

    Quantized modes move codes + fp32 block scales; leaves at or under the
    threshold (and all leaves in fp32/bf16 modes) move as raw casts.
    """
    n = _numel(shape)
    fp32 = n * 4
    qcfg = config.quant_config()
    if qcfg is not None and n > config.threshold:
        return fp32, quantized_nbytes(shape, qcfg)
    if config.cast_dtype is not None:
        return fp32, n * 2
    return fp32, fp32


def wire_report(grads_tree, config: CommsConfig) -> Dict:
    """Per-leaf and total gradient-collective bytes for one train step.

    ``grads_tree`` is any tree of array-likes with ``.shape`` (the gradient
    tree has the parameter tree's shapes, so passing params — concrete or
    abstract — is the common call).
    """
    leaves = jax.tree_util.tree_leaves(grads_tree)
    paths = jax.tree_util.tree_leaves(tree_paths(grads_tree))
    rows: List[Dict] = []
    total_fp32 = total_wire = 0
    quantized_leaves = 0
    qcfg = config.quant_config()
    for path, leaf in zip(paths, leaves):
        shape = tuple(leaf.shape)
        fp32, wire = leaf_wire_bytes(shape, config)
        quantized = qcfg is not None and _numel(shape) > config.threshold
        quantized_leaves += int(quantized)
        rows.append(
            {
                "path": path,
                "shape": shape,
                "fp32_bytes": fp32,
                "wire_bytes": wire,
                "quantized": quantized,
            }
        )
        total_fp32 += fp32
        total_wire += wire
    return {
        "mode": config.mode,
        "name": config.name,
        "leaves": rows,
        "n_leaves": len(rows),
        "quantized_leaves": quantized_leaves,
        "total_fp32_bytes": int(total_fp32),
        "total_wire_bytes": int(total_wire),
        "ratio_vs_fp32": round(total_fp32 / total_wire, 4) if total_wire else 1.0,
    }


def mode_totals(grads_tree, modes=GRAD_COMM_MODES) -> List[Dict]:
    """One ``wire_report`` summary per mode (the trade-off table's spine)."""
    return [wire_report(grads_tree, CommsConfig(mode=m)) for m in modes]


def format_wire_table(reports: List[Dict], title: str = "") -> str:
    """Markdown bytes-on-the-wire table (CI step summary / docs)."""
    lines = []
    if title:
        lines += [f"### {title}", ""]
    lines += [
        "| grad-comm | wire format | collective bytes/step | vs fp32 | quantized leaves |",
        "|---|---|---|---|---|",
    ]
    for r in reports:
        lines.append(
            f"| {r['mode']} | {r['name']} | {r['total_wire_bytes']:,} "
            f"| {r['ratio_vs_fp32']:.2f}x fewer "
            f"| {r['quantized_leaves']}/{r['n_leaves']} |"
        )
    return "\n".join(lines)
