"""CommsConfig: the one gradient-compression knob (``--grad-comm``).

Four wire formats for the cross-device gradient reduction:

* ``fp32`` — today's baseline: fp32 gradients move through the collective.
* ``bf16`` — cast-before-transport (the legacy ``grad_dtype=bf16`` lever,
  folded in here; half the collective bytes).
* ``int8`` — block-wise quantized transport: uint8 codes + one fp32 absmax
  scale per ``block_size`` elements (~3.9x fewer bytes at B128).
* ``int4`` — nibble-packed codes + block scales (~7.5x fewer bytes at B128).

Quantized modes reuse the 4-bit-optimizer stack end to end: the signed
mappings/normalizers from ``core/quantizer.py`` and — when the train state
carries an SR base key — stochastic rounding keyed off the checkpointed
``fold_in(TrainState.key, step)`` stream, so the transport noise is a pure
function of checkpointed state (bit-reproducible across resume and across
elastic mesh restarts).  Leaves with at most ``threshold`` elements
(biases, norm scales) always move fp32, mirroring the optimizer-state
policy (paper App. D.1).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from repro.core.quantizer import QuantConfig

__all__ = ["GRAD_COMM_MODES", "CommsConfig"]

GRAD_COMM_MODES = ("fp32", "bf16", "int8", "int4")

# Domain tag folded into the per-step SR key before per-leaf folds, so the
# gradient-transport noise stream never collides with the optimizer-state
# SR stream (which folds small leaf indices into the same step key).
GRAD_COMM_KEY_DOMAIN = 0x67726164  # ASCII "grad"


@dataclasses.dataclass(frozen=True)
class CommsConfig:
    """Static description of the gradient-collective wire format (hashable)."""

    mode: str = "fp32"
    block_size: int = 128
    mapping: str = "de"  # signed map WITH a zero code (gradients are sparse-ish)
    stochastic_rounding: bool = True
    threshold: int = 4096  # leaves <= threshold elements move fp32 (App. D.1)

    def __post_init__(self):
        if self.mode not in GRAD_COMM_MODES:
            raise ValueError(
                f"unknown grad-comm mode {self.mode!r}; want one of {GRAD_COMM_MODES}"
            )
        # Validate the mapping eagerly (with the registry's did-you-mean)
        # even for non-quantized modes, so a typo'd config fails at
        # construction rather than when someone later flips mode="int4".
        from repro.core import mappings

        mappings.get_spec(self.mapping)

    @classmethod
    def parse(cls, mode: str, **overrides) -> "CommsConfig":
        """Build from the CLI spelling (``--grad-comm int4``)."""
        return cls(mode=str(mode).lower(), **overrides)

    # -- wire-format properties ------------------------------------------
    @property
    def bits(self) -> Optional[int]:
        return {"int8": 8, "int4": 4}.get(self.mode)

    @property
    def quantized(self) -> bool:
        return self.mode in ("int8", "int4")

    @property
    def compresses(self) -> bool:
        """Any mode that changes what moves through the collective."""
        return self.mode != "fp32"

    @property
    def cast_dtype(self):
        return jnp.bfloat16 if self.mode == "bf16" else None

    def quant_config(self) -> Optional[QuantConfig]:
        """The ``core/quantizer`` config of the transport quantizer."""
        if not self.quantized:
            return None
        return QuantConfig(
            bits=self.bits,
            normalization="blockwise",
            block_size=self.block_size,
            mapping=self.mapping,
            signed=True,
            stochastic_rounding=self.stochastic_rounding,
            threshold=self.threshold,
        )

    @property
    def name(self) -> str:
        if not self.quantized:
            return self.mode
        sr = "+SR" if self.stochastic_rounding else ""
        return f"{self.mode}/B{self.block_size}/{self.mapping.upper()}{sr}"
