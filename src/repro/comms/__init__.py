"""Quantized gradient communication: the layer between the train step and
the mesh.

``CommsConfig`` is the one gradient-compression knob (``--grad-comm
{fp32,bf16,int8,int4}``); ``reduce_grads`` applies the configured wire
format to the gradient tree inside the train step; ``quantized_all_reduce``
is the shard_map-level dequantize-and-sum primitive; ``accounting`` owns
bytes-on-the-wire reporting.  See docs/comms.md.
"""

from repro.comms.accounting import (
    format_wire_table,
    leaf_wire_bytes,
    mode_totals,
    wire_report,
)
from repro.comms.config import GRAD_COMM_MODES, CommsConfig
from repro.comms.reduce import grad_comm_key, quantized_all_reduce, reduce_grads

__all__ = [
    "GRAD_COMM_MODES",
    "CommsConfig",
    "grad_comm_key",
    "quantized_all_reduce",
    "reduce_grads",
    "leaf_wire_bytes",
    "wire_report",
    "mode_totals",
    "format_wire_table",
]
