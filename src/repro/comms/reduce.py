"""Quantized gradient-collective primitives.

Two layers:

``quantized_all_reduce``
    The wire-format primitive, for use inside ``shard_map`` over the
    data-parallel mesh axes.  Each rank block-quantizes its *local partial*
    gradient (codes + absmax scales), the collective all-gathers codes and
    scales (that is what moves on the wire — uint8 instead of fp32), and
    every rank dequantizes each participant's contribution and sums in a
    fixed rank order.  With stochastic rounding the per-rank key is
    ``fold_in(key, axis_index)``, so the transported noise is a pure
    function of (key, rank) — deterministic and replayable.

``reduce_grads``
    The train-step integration that replaces the ad-hoc ``grad_dtype``
    cast in ``train_loop._constrain_grads_zero``.  The gradients arriving
    here are SPMD-global (autodiff already summed over data parallelism),
    so the quantized modes apply the transport quantizer to the logical
    gradient — quantize (SR keyed off the checkpointed step key) ->
    constrain the *codes and scales* to the ZeRO wire layout (the
    resharding collective moves compressed bytes) -> dequantize into fp32
    for the optimizer.  Numerically this is transport quantization applied
    once per reduction; because every op is elementwise or an exact
    (max/reshape) block statistic of the logical tensor, the result is
    bit-identical for any mesh layout given the same logical gradients —
    the property the elastic-restart tests pin down.

Stochastic-rounding noise is generated with the counter-based Threefry of
``repro.kernels.sr`` (counter = the leaf's flattened global element index,
stream ``STREAM_GRAD``), NOT ``jax.random.uniform``: under jax's default
non-partitionable Threefry lowering, ``uniform`` draws depend on the output
sharding, which would silently break the cross-mesh bit-reproducibility
promise above.  The counter derivation replays identical bits per
(key, element) on any mesh — the same trick the fused optimizer kernel uses
for tiling-invariant in-kernel SR.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.comms.config import GRAD_COMM_KEY_DOMAIN, CommsConfig
from repro.core.quantizer import QuantConfig, QuantizedTensor, dequantize, quantize
from repro.kernels.sr import STREAM_GRAD, tensor_uniforms
from repro.sharding.rules import wire_spec

__all__ = ["quantized_all_reduce", "reduce_grads", "grad_comm_key"]

_IS_AXES_LEAF = lambda a: isinstance(a, tuple) and all(isinstance(s, str) for s in a)


def grad_comm_key(
    base_key: Optional[jax.Array], step: jnp.ndarray
) -> Optional[jax.Array]:
    """Per-step transport SR key: a pure function of the checkpointed
    ``(TrainState.key, step)`` pair, domain-separated from the optimizer's
    state-quantization stream (which folds bare leaf indices into the same
    ``fold_in(key, step)``)."""
    if base_key is None:
        return None
    step_key = jax.random.fold_in(base_key, step)
    return jax.random.fold_in(step_key, GRAD_COMM_KEY_DOMAIN)


def quantized_all_reduce(
    x: jnp.ndarray,
    config: QuantConfig,
    axis_name,
    key: Optional[jax.Array] = None,
) -> jnp.ndarray:
    """Sum ``x`` over ``axis_name`` moving codes+scales, not fp32.

    For use inside ``shard_map``: ``x`` is this rank's partial sum.  Returns
    ``sum_r dequantize(quantize(x_r))`` — the dequantize-and-sum schedule, in
    ascending rank order on every rank (deterministic, rank-count exact).
    """
    u = None
    if key is not None and config.stochastic_rounding:
        key = jax.random.fold_in(key, jax.lax.axis_index(axis_name))
        u = tensor_uniforms(key, tuple(x.shape), STREAM_GRAD)
    q = quantize(x, config, uniforms=u)
    # The collective: codes (uint8) + scales (fp32 block absmax) on the wire.
    codes = jax.lax.all_gather(q.codes, axis_name)
    scales = tuple(jax.lax.all_gather(s, axis_name) for s in q.scales)

    def deq_one(c, ss):
        return dequantize(QuantizedTensor(c, ss, x.shape, config))

    return jnp.sum(jax.vmap(deq_one)(codes, scales), axis=0)


def _transport_quantize(
    g: jnp.ndarray,
    qcfg: QuantConfig,
    axes: Optional[Tuple[str, ...]],
    mesh: Optional[Mesh],
    key: Optional[jax.Array],
) -> jnp.ndarray:
    """Quantize -> constrain codes/scales to the wire layout -> dequantize."""
    u = (
        tensor_uniforms(key, tuple(g.shape), STREAM_GRAD)
        if key is not None and qcfg.stochastic_rounding
        else None
    )
    q = quantize(g.astype(jnp.float32), qcfg, uniforms=u)
    codes, scales = q.codes, q.scales
    if mesh is not None and axes is not None and len(axes) == codes.ndim:
        # The compressed payload is what reshards into the ZeRO layout.
        spec = wire_spec(tuple(codes.shape), axes, mesh)
        codes = jax.lax.with_sharding_constraint(codes, NamedSharding(mesh, spec))
    out = dequantize(QuantizedTensor(codes, scales, q.shape, qcfg))
    if mesh is not None and axes is not None:
        spec = wire_spec(tuple(out.shape), axes, mesh)
        out = jax.lax.with_sharding_constraint(out, NamedSharding(mesh, spec))
    return out


def reduce_grads(
    grads,
    axes,
    mesh: Optional[Mesh],
    config: CommsConfig,
    *,
    key: Optional[jax.Array] = None,
):
    """Apply the configured gradient-collective wire format to a grad tree.

    * ``fp32``  — constrain each leaf to the ZeRO layout (reduce-scatter),
      exactly the legacy ``_constrain_grads_zero`` behaviour.
    * ``bf16``  — cast before the constraint (half the collective bytes);
      leaves stay bf16 downstream, matching the legacy ``grad_dtype`` path
      bit for bit.
    * ``int8``/``int4`` — transport quantization per leaf (see module
      docstring).  Leaves with <= ``config.threshold`` elements move fp32.

    ``mesh=None`` applies the numerics without layout constraints (the
    single-process benchmark path measures exactly the quantization error a
    mesh run pays).  ``key`` (from ``grad_comm_key``) enables stochastic
    rounding; without it quantized modes fall back to round-to-nearest.
    """
    g_leaves, treedef = jax.tree_util.tree_flatten(grads)
    if axes is not None:
        a_leaves = jax.tree_util.tree_leaves(axes, is_leaf=_IS_AXES_LEAF)
    else:
        a_leaves = [None] * len(g_leaves)
    qcfg = config.quant_config()
    out = []
    for i, (g, a) in enumerate(zip(g_leaves, a_leaves)):
        quantize_leaf = qcfg is not None and g.size > config.threshold
        if quantize_leaf:
            leaf_key = jax.random.fold_in(key, i) if key is not None else None
            g = _transport_quantize(g, qcfg, a, mesh, leaf_key)
        else:
            if config.cast_dtype is not None:
                g = g.astype(config.cast_dtype)
            if mesh is not None and a is not None:
                spec = wire_spec(tuple(g.shape), a, mesh)
                g = jax.lax.with_sharding_constraint(g, NamedSharding(mesh, spec))
        out.append(g)
    return jax.tree_util.tree_unflatten(treedef, out)
