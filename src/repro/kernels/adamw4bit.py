"""Fused 4-bit AdamW update as a Pallas TPU kernel.

This is the paper's "fused operator" (Tab. 4's `4-bit AdamW (fused)` row)
adapted to TPU: one kernel pass reads packed 4-bit moment codes + params +
grads tile-by-tile from HBM into VMEM, dequantizes on the VPU, applies the
AdamW step (Eq. 1) in fp32, requantizes, and writes packed codes + updated
params back — the precise fp32 moments never round-trip through HBM.

TPU adaptation (vs the CUDA original):
  * table lookup is a branchless 16-way select tree (no per-thread binary
    search; the 16-entry table lives in VMEM / VREGs),
  * encoding is a midpoint compare-and-sum: idx = sum_k [n > mid_k],
  * nibble pack/unpack are lane-local shifts on the last axis,
  * first-moment B128 block scales are computed inside the tile (tile cols
    are multiples of 128, so blocks never straddle tiles),
  * second-moment rank-1 scales of the NEW v need global row/col maxes, so
    they are computed in a prepass (XLA fuses dequant+max; nothing fp32 is
    materialized in HBM) and fed to the kernel — the two-pass structure that
    replaces CUDA's atomics-based reduction.

Tiles are (TR, TC) with TC a multiple of 256 so that packed code tiles
(TC/2) and B128 scale tiles (TC/128) stay integral.

Stacked leaves run as ONE launch: ``fused_adamw4`` takes (L, R, C) operands
and a 3-d grid (L, R/TR, C/TC) whose outer dim walks the leading slices — no
per-slice Python loop, no L-unrolled jaxpr, one kernel launch per leaf.  Per
slice the v scale is ``min(row_stat, col_stat)`` with per-slice row stats
(L, R) and column stats (C,) shared across slices (rank-1 stats are global
per-dim vectors; leading-dim stats fold into the row stat upstream).  2-d
operands are accepted and treated as L == 1.

Stochastic rounding (``use_sr=True``) requantizes both moments with
counter-based Threefry-2x32 noise generated *inside* the tile: the counter is
the element's global index in its (R, C) slice, the key the slice's row of
the (L, 2) seed input (indexed by the outer grid dim), and the stream id
separates m from v — so the noise is a pure function of (key, element),
independent of tiling, mesh layout, AND of whether slices launch separately
or through the 3-d grid; it is bit-identical to the pure-jnp SR oracle in
``ref.py`` (see ``sr.py``).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.sr import (
    STREAM_M,
    STREAM_V,
    threefry2x32,
    uniform_from_bits,
)

__all__ = ["fused_adamw4", "TILE_R", "TILE_C"]

TILE_R = 128
TILE_C = 512
_BLOCK = 128  # first-moment block size (B128)


def _decode16(codes, table_ref):
    """Branchless 16-way select: vals[i] = table[codes[i]]."""
    acc = jnp.zeros(codes.shape, jnp.float32)
    for k in range(16):
        acc = jnp.where(codes == k, table_ref[0, k], acc)
    return acc


def _encode16(n, table_ref, num_points: int):
    """Round-to-nearest codes via midpoint compare-and-sum."""
    idx = jnp.zeros(n.shape, jnp.int32)
    for k in range(num_points - 1):
        mid = (table_ref[0, k] + table_ref[0, k + 1]) * 0.5
        idx = idx + (n > mid).astype(jnp.int32)
    return idx.astype(jnp.uint8)


def _encode16_sr(n, table_ref, num_points: int, u):
    """Stochastic codes: round to the bracketing table points with probability
    proportional to proximity, deciding with the uniform draw ``u``.

    Same bracketing/probability math as ``mappings.encode_stochastic`` /
    ``ref.encode_table_stochastic_bits`` (branchless select-tree form), so the
    kernel's SR codes match the jnp oracle bit-for-bit given the same ``u``.
    """
    ge = jnp.zeros(n.shape, jnp.int32)
    for k in range(num_points):
        ge = ge + (n >= table_ref[0, k]).astype(jnp.int32)
    lo = jnp.clip(ge - 1, 0, num_points - 2)
    t_lo = jnp.zeros(n.shape, jnp.float32)
    t_hi = jnp.zeros(n.shape, jnp.float32)
    for k in range(num_points - 1):
        t_lo = jnp.where(lo == k, table_ref[0, k], t_lo)
        t_hi = jnp.where(lo == k, table_ref[0, k + 1], t_hi)
    span = jnp.maximum(t_hi - t_lo, 1e-12)
    p_hi = jnp.clip((n - t_lo) / span, 0.0, 1.0)
    idx = lo + (u < p_hi).astype(jnp.int32)
    return idx.astype(jnp.uint8)


def _tile_uniforms(seed_ref, tile_shape, full_cols: int, stream: int):
    """Per-element uniforms for this tile, counter = slice-local r * C + c.

    Keyed on (per-slice seed words, element index, moment stream) — the
    in-kernel twin of ``sr.element_uniforms``, evaluated tile-locally so no
    random tensor ever touches HBM.  The counter is the element's index in
    its own (R, C) slice (grid dims 1 and 2; the outer slice dim selects the
    seed row instead of shifting the counter), so the bits equal what a
    standalone per-slice launch would draw.
    """
    i = pl.program_id(1)
    j = pl.program_id(2)
    tr, tc = tile_shape
    rows = jax.lax.broadcasted_iota(jnp.uint32, (tr, tc), 0) + (i * tr).astype(
        jnp.uint32
    )
    cols = jax.lax.broadcasted_iota(jnp.uint32, (tr, tc), 1) + (j * tc).astype(
        jnp.uint32
    )
    linear = rows * jnp.uint32(full_cols) + cols
    bits, _ = threefry2x32(
        seed_ref[0, 0], seed_ref[0, 1], linear, jnp.uint32(stream)
    )
    return uniform_from_bits(bits)


def _unpack(packed):
    lo = packed & jnp.uint8(0x0F)
    hi = (packed >> 4) & jnp.uint8(0x0F)
    return jnp.stack([lo, hi], axis=-1).reshape(packed.shape[0], -1)


def _pack(codes):
    pairs = codes.reshape(codes.shape[0], -1, 2)
    return (pairs[..., 0] | (pairs[..., 1] << 4)).astype(jnp.uint8)


def _guard(s):
    return jnp.where(s > 0, s, jnp.ones_like(s))


def pick_tile_r(R: int, cap: int = TILE_R) -> int:
    """Largest divisor of R that is <= cap."""
    for d in range(min(R, cap), 0, -1):
        if R % d == 0:
            return d
    return 1


def pick_tile_c(C: int, cap: int = TILE_C) -> int:
    """Largest multiple-of-256 divisor of C that is <= cap (C % 256 == 0)."""
    best = 256
    d = 256
    while d <= min(C, cap):
        if C % d == 0:
            best = d
        d += 256
    return best


def _kernel(
    # inputs
    w_ref, g_ref, m_packed_ref, m_scale_ref, v_packed_ref,
    vr_ref, vc_ref, vr_new_ref, vc_new_ref,
    scalars_ref, m_table_ref, v_table_ref, seed_ref,
    # outputs
    w_out_ref, m_packed_out_ref, m_scale_out_ref, v_packed_out_ref,
    *, m_points: int, v_points: int, full_cols: int, use_sr: bool,
):
    lr = scalars_ref[0, 0]
    b1 = scalars_ref[0, 1]
    b2 = scalars_ref[0, 2]
    eps = scalars_ref[0, 3]
    wd = scalars_ref[0, 4]
    bc1 = scalars_ref[0, 5]
    bc2 = scalars_ref[0, 6]

    # Tensor blocks carry a leading slice dim of extent 1 (the outer grid
    # dim selects which slice); [0] views them as the 2-d tile.
    w = w_ref[0].astype(jnp.float32)
    g = g_ref[0].astype(jnp.float32)
    tr, tc = w.shape

    # ---- decompress (Alg. 1 line 3) ----------------------------------
    m_codes = _unpack(m_packed_ref[0])
    m_vals = _decode16(m_codes, m_table_ref)
    m_scale = m_scale_ref[0]  # (TR, TC/128)
    m = m_vals * jnp.repeat(m_scale, _BLOCK, axis=1)

    v_codes = _unpack(v_packed_ref[0])
    v_vals = _decode16(v_codes, v_table_ref)
    v_scale = _guard(jnp.minimum(vr_ref[0], vc_ref[...]))  # (TR,1)x(1,TC)
    v = v_vals * v_scale

    # ---- inner optimizer A: AdamW (Eq. 1) -----------------------------
    m_new = b1 * m + (1.0 - b1) * g
    v_new = b2 * v + (1.0 - b2) * g * g
    u = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
    w_out_ref[0] = (w - lr * (u + wd * w)).astype(w_out_ref.dtype)

    # ---- compress (Alg. 1 line 5) -------------------------------------
    m_blocks = m_new.reshape(tr, tc // _BLOCK, _BLOCK)
    m_scale_new = _guard(jnp.max(jnp.abs(m_blocks), axis=-1))  # (TR, TC/128)
    m_scale_out_ref[0] = m_scale_new
    m_n = (m_blocks / m_scale_new[..., None]).reshape(tr, tc)
    if use_sr:
        u_m = _tile_uniforms(seed_ref, (tr, tc), full_cols, STREAM_M)
        m_codes = _encode16_sr(m_n, m_table_ref, m_points, u_m)
    else:
        m_codes = _encode16(m_n, m_table_ref, m_points)
    m_packed_out_ref[0] = _pack(m_codes)

    v_scale_new = _guard(jnp.minimum(vr_new_ref[0], vc_new_ref[...]))
    v_n = v_new / v_scale_new
    if use_sr:
        u_v = _tile_uniforms(seed_ref, (tr, tc), full_cols, STREAM_V)
        v_codes = _encode16_sr(v_n, v_table_ref, v_points, u_v)
    else:
        v_codes = _encode16(v_n, v_table_ref, v_points)
    v_packed_out_ref[0] = _pack(v_codes)


@functools.partial(
    jax.jit,
    static_argnames=(
        "b1", "b2", "eps", "weight_decay", "interpret", "tile_r", "tile_c", "use_sr",
    ),
)
def fused_adamw4(
    w: jnp.ndarray,          # (L, R, C) — or (R, C), treated as L == 1
    g: jnp.ndarray,          # like w
    m_packed: jnp.ndarray,   # (L, R, C/2) uint8
    m_scale: jnp.ndarray,    # (L, R, C/128) f32
    v_packed: jnp.ndarray,   # (L, R, C/2) uint8
    v_r: jnp.ndarray,        # (L, R) f32 — old per-slice rank-1 row stats
    v_c: jnp.ndarray,        # (C,) f32 — old rank-1 col stats (shared)
    v_r_new: jnp.ndarray,    # (L, R) f32 — precomputed stats of updated v
    v_c_new: jnp.ndarray,    # (C,) f32
    m_table: jnp.ndarray,    # (16,) signed (DE) table
    v_table: jnp.ndarray,    # (<=16,) unsigned (Linear) table
    lr: jnp.ndarray,
    bc1: jnp.ndarray,        # 1 - b1^t
    bc2: jnp.ndarray,        # 1 - b2^t
    sr_seed: Optional[jnp.ndarray] = None,  # (L, 2) uint32 per-slice key rows
    *,
    b1: float,
    b2: float,
    eps: float,
    weight_decay: float,
    interpret: bool = False,
    tile_r: int = TILE_R,
    tile_c: int = TILE_C,
    use_sr: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Run the fused update as ONE 3-d-grid launch over all stacked slices.

    The grid is (L, R/TR, C/TC); the outer dim walks the leading slices and
    selects each slice's row-stat block and SR seed row.  Because the SR
    counter stays slice-local, outputs are bit-identical to launching the 2-d
    kernel once per slice.  2-d operands are accepted (L == 1, stats ``(R,)``
    / seed ``(2,)``) and return 2-d outputs.

    ``use_sr=True`` requantizes stochastically with in-tile Threefry noise
    keyed by ``sr_seed`` (required in that case); ``use_sr=False`` is the
    bit-exact round-to-nearest path.

    Returns (w_new, m_packed_new, m_scale_new, v_packed_new).
    """
    squeeze = w.ndim == 2
    if squeeze:
        (R, C), L = w.shape, 1
    else:
        L, R, C = w.shape
    tr = pick_tile_r(R, tile_r)
    tc = pick_tile_c(C, tile_c)
    assert R % tr == 0 and C % tc == 0 and tc % 256 == 0, (R, C, tr, tc)
    grid = (L, R // tr, C // tc)

    # Pad tables to 16 (select tree is fixed-width); extra entries unused.
    def pad16(t):
        t = t.astype(jnp.float32)
        return jnp.pad(t, (0, 16 - t.shape[0])).reshape(1, 16)

    m_points = int(m_table.shape[0])
    v_points = int(v_table.shape[0])

    if use_sr and sr_seed is None:
        raise ValueError("fused_adamw4(use_sr=True) requires sr_seed")
    seed = (
        jnp.zeros((L, 2), jnp.uint32)
        if sr_seed is None
        else jnp.asarray(sr_seed, jnp.uint32).reshape(L, 2)
    )

    scalars = jnp.stack(
        [
            jnp.asarray(lr, jnp.float32),
            jnp.float32(b1),
            jnp.float32(b2),
            jnp.float32(eps),
            jnp.float32(weight_decay),
            jnp.asarray(bc1, jnp.float32),
            jnp.asarray(bc2, jnp.float32),
            jnp.float32(0.0),
        ]
    ).reshape(1, 8)

    full = lambda shape: pl.BlockSpec(shape, lambda l, i, j: (0, 0))
    row = pl.BlockSpec((1, tr, 1), lambda l, i, j: (l, i, 0))
    col = lambda blk: pl.BlockSpec((1, blk), lambda l, i, j: (0, j))
    tile = lambda c: pl.BlockSpec((1, tr, c), lambda l, i, j: (l, i, j))
    seed_row = pl.BlockSpec((1, 2), lambda l, i, j: (l, 0))

    out_shapes = (
        jax.ShapeDtypeStruct((L, R, C), w.dtype),
        jax.ShapeDtypeStruct((L, R, C // 2), jnp.uint8),
        jax.ShapeDtypeStruct((L, R, C // _BLOCK), jnp.float32),
        jax.ShapeDtypeStruct((L, R, C // 2), jnp.uint8),
    )

    kernel = functools.partial(
        _kernel, m_points=m_points, v_points=v_points, full_cols=C, use_sr=use_sr
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            tile(tc),                 # w
            tile(tc),                 # g
            tile(tc // 2),            # m_packed
            tile(tc // _BLOCK),       # m_scale
            tile(tc // 2),            # v_packed
            row,                      # v_r (L,R,1)
            col(tc),                  # v_c (1,C)
            row,                      # v_r_new
            col(tc),                  # v_c_new
            full((1, 8)),             # scalars
            full((1, 16)),            # m_table
            full((1, 16)),            # v_table
            seed_row,                 # SR seed rows (one (2,) key per slice)
        ],
        out_specs=[
            tile(tc),                 # w_new
            tile(tc // 2),            # m_packed_new
            tile(tc // _BLOCK),       # m_scale_new
            tile(tc // 2),            # v_packed_new
        ],
        out_shape=out_shapes,
        interpret=interpret,
    )(
        w.reshape(L, R, C),
        g.reshape(L, R, C),
        m_packed.reshape(L, R, C // 2),
        m_scale.reshape(L, R, C // _BLOCK),
        v_packed.reshape(L, R, C // 2),
        v_r.reshape(L, R, 1),
        v_c.reshape(1, C),
        v_r_new.reshape(L, R, 1),
        v_c_new.reshape(1, C),
        scalars,
        pad16(m_table),
        pad16(v_table),
        seed,
    )
    if squeeze:
        out = tuple(o.reshape(o.shape[1:]) for o in out)
    return out
