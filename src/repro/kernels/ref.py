"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth the kernels must match bit-for-bit (codes) /
allclose (floats). They intentionally mirror the kernel's *structured* layout:
2-d tensors with the last dim a multiple of 256 (so nibble pairs and B128
blocks never straddle tiles), m quantized B128/<table> per row-major block,
v quantized rank-1/<table> with externally supplied new scales.

Every function here is vmap-safe (shape-generic jnp ops, no data-dependent
Python): ``ops.fused_adamw4_leaf``'s ref backend vmaps
``fused_adamw4_reference`` / ``fused_adamw4_sr_reference`` over the leading
slice dim of stacked leaves, tracing O(1) equations regardless of depth —
the oracle twin of the kernel's single 3-d-grid launch.  Keep new helpers
free of per-call Python loops over array contents for the same reason.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.kernels.sr import STREAM_M, STREAM_V, element_uniforms

__all__ = [
    "unpack_codes",
    "pack_codes",
    "dequant_blockwise",
    "dequant_rank1",
    "encode_table",
    "encode_table_stochastic_bits",
    "fused_adamw4_reference",
    "fused_adamw4_sr_reference",
]


def unpack_codes(packed: jnp.ndarray) -> jnp.ndarray:
    """(R, C/2) uint8 -> (R, C) uint8 codes (low nibble first)."""
    lo = packed & jnp.uint8(0x0F)
    hi = (packed >> 4) & jnp.uint8(0x0F)
    return jnp.stack([lo, hi], axis=-1).reshape(packed.shape[0], -1)


def pack_codes(codes: jnp.ndarray) -> jnp.ndarray:
    """(R, C) uint8 -> (R, C/2) uint8 (low nibble first)."""
    pairs = codes.reshape(codes.shape[0], -1, 2)
    return (pairs[..., 0] | (pairs[..., 1] << 4)).astype(jnp.uint8)


def decode_table(codes: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table, codes.astype(jnp.int32), axis=0)


def encode_table(n: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    mids = (table[1:] + table[:-1]) / 2.0
    return jnp.sum(n[..., None] > mids, axis=-1).astype(jnp.uint8)


def encode_table_stochastic_bits(
    n: jnp.ndarray, table: jnp.ndarray, u: jnp.ndarray
) -> jnp.ndarray:
    """Stochastic codes driven by explicit uniforms ``u`` in [0, 1).

    Identical bracketing/probability math as the in-kernel ``_encode16_sr``
    (and ``mappings.encode_stochastic``), so the fused kernel's SR codes are
    reproducible bit-for-bit by feeding the same counter-derived uniforms.
    """
    k = table.shape[0]
    lo = jnp.clip(jnp.sum(n[..., None] >= table, axis=-1) - 1, 0, k - 2)
    t_lo = jnp.take(table, lo, axis=0)
    t_hi = jnp.take(table, lo + 1, axis=0)
    span = jnp.maximum(t_hi - t_lo, 1e-12)
    p_hi = jnp.clip((n - t_lo) / span, 0.0, 1.0)
    idx = lo + (u < p_hi).astype(lo.dtype)
    return idx.astype(jnp.uint8)


def _guard(s: jnp.ndarray) -> jnp.ndarray:
    return jnp.where(s > 0, s, jnp.ones_like(s))


def dequant_blockwise(
    packed: jnp.ndarray, scale: jnp.ndarray, table: jnp.ndarray, block: int = 128
) -> jnp.ndarray:
    """packed (R, C/2), scale (R, C/block) -> (R, C) fp32."""
    codes = unpack_codes(packed)
    vals = decode_table(codes, table)
    R, C = vals.shape
    per_elem = jnp.repeat(scale, block, axis=1)
    return vals * per_elem


def dequant_rank1(
    packed: jnp.ndarray, r: jnp.ndarray, c: jnp.ndarray, table: jnp.ndarray
) -> jnp.ndarray:
    """packed (R, C/2), r (R,), c (C,) -> (R, C) fp32."""
    codes = unpack_codes(packed)
    vals = decode_table(codes, table)
    scale = _guard(jnp.minimum(r[:, None], c[None, :]))
    return vals * scale


def quant_blockwise(
    x: jnp.ndarray, table: jnp.ndarray, block: int = 128
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(R, C) -> packed (R, C/2), scale (R, C/block)."""
    R, C = x.shape
    blocks = x.reshape(R, C // block, block)
    scale = _guard(jnp.max(jnp.abs(blocks), axis=-1))  # (R, C/block)
    n = (blocks / scale[..., None]).reshape(R, C)
    codes = encode_table(n, table)
    return pack_codes(codes), scale


def quant_rank1_given_scales(
    x: jnp.ndarray, r: jnp.ndarray, c: jnp.ndarray, table: jnp.ndarray
) -> jnp.ndarray:
    """(R, C) with given new rank-1 stats -> packed codes (R, C/2)."""
    scale = _guard(jnp.minimum(r[:, None], c[None, :]))
    codes = encode_table(x / scale, table)
    return pack_codes(codes)


def fused_adamw4_reference(
    w: jnp.ndarray,          # (R, C) param
    g: jnp.ndarray,          # (R, C) grad
    m_packed: jnp.ndarray,   # (R, C/2)
    m_scale: jnp.ndarray,    # (R, C/128)
    v_packed: jnp.ndarray,   # (R, C/2)
    v_r: jnp.ndarray,        # (R,)
    v_c: jnp.ndarray,        # (C,)
    m_table: jnp.ndarray,    # (16,) signed DE
    v_table: jnp.ndarray,    # (16,) unsigned Linear
    lr: jnp.ndarray,
    b1: float,
    b2: float,
    eps: float,
    weight_decay: float,
    bc1: jnp.ndarray,
    bc2: jnp.ndarray,
    v_r_new: jnp.ndarray = None,
    v_c_new: jnp.ndarray = None,
):
    """Oracle for the fused kernel: dequant -> AdamW (Eq. 1) -> requant.

    Returns (w_new, m_packed_new, m_scale_new, v_packed_new, v_r_new, v_c_new).
    New rank-1 scales are row/col maxes of the updated v (the kernel receives
    them precomputed — the two-pass structure described in DESIGN.md §3);
    pass ``v_r_new``/``v_c_new`` explicitly when the slice is part of a larger
    stacked leaf whose rank-1 stats are global (see ``ops.fused_adamw4_leaf``).
    """
    g32 = g.astype(jnp.float32)
    m = dequant_blockwise(m_packed, m_scale, m_table)
    v = dequant_rank1(v_packed, v_r, v_c, v_table)

    m_new = b1 * m + (1.0 - b1) * g32
    v_new = b2 * v + (1.0 - b2) * g32 * g32

    u = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
    w_new = (w.astype(jnp.float32) - lr * (u + weight_decay * w.astype(jnp.float32))).astype(w.dtype)

    m_packed_new, m_scale_new = quant_blockwise(m_new, m_table)
    if v_r_new is None:
        v_r_new = jnp.max(v_new, axis=1)
    if v_c_new is None:
        v_c_new = jnp.max(v_new, axis=0)
    v_packed_new = quant_rank1_given_scales(v_new, v_r_new, v_c_new, v_table)
    return w_new, m_packed_new, m_scale_new, v_packed_new, v_r_new, v_c_new


def fused_adamw4_sr_reference(
    w: jnp.ndarray,
    g: jnp.ndarray,
    m_packed: jnp.ndarray,
    m_scale: jnp.ndarray,
    v_packed: jnp.ndarray,
    v_r: jnp.ndarray,
    v_c: jnp.ndarray,
    m_table: jnp.ndarray,
    v_table: jnp.ndarray,
    lr: jnp.ndarray,
    b1: float,
    b2: float,
    eps: float,
    weight_decay: float,
    bc1: jnp.ndarray,
    bc2: jnp.ndarray,
    seed: jnp.ndarray,  # (2,) uint32 per-slice key words
    v_r_new: jnp.ndarray = None,
    v_c_new: jnp.ndarray = None,
):
    """Stochastic-rounding oracle for the fused kernel.

    Identical to ``fused_adamw4_reference`` except both moments requantize
    stochastically, with uniforms derived from counter-based Threefry on the
    element index (``sr.element_uniforms``) — the exact bits the Pallas kernel
    draws in-tile, so codes match the kernel bit-for-bit given ``seed``.
    """
    g32 = g.astype(jnp.float32)
    m = dequant_blockwise(m_packed, m_scale, m_table)
    v = dequant_rank1(v_packed, v_r, v_c, v_table)

    m_new = b1 * m + (1.0 - b1) * g32
    v_new = b2 * v + (1.0 - b2) * g32 * g32

    u = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
    w_new = (w.astype(jnp.float32) - lr * (u + weight_decay * w.astype(jnp.float32))).astype(w.dtype)

    R, C = w.shape
    u_m = element_uniforms(seed[0], seed[1], (R, C), STREAM_M)
    u_v = element_uniforms(seed[0], seed[1], (R, C), STREAM_V)

    blocks = m_new.reshape(R, C // 128, 128)
    m_scale_new = _guard(jnp.max(jnp.abs(blocks), axis=-1))
    m_n = (blocks / m_scale_new[..., None]).reshape(R, C)
    m_packed_new = pack_codes(encode_table_stochastic_bits(m_n, m_table, u_m))

    if v_r_new is None:
        v_r_new = jnp.max(v_new, axis=1)
    if v_c_new is None:
        v_c_new = jnp.max(v_new, axis=0)
    v_scale_new = _guard(jnp.minimum(v_r_new[:, None], v_c_new[None, :]))
    v_n = v_new / v_scale_new
    v_packed_new = pack_codes(encode_table_stochastic_bits(v_n, v_table, u_v))
    return w_new, m_packed_new, m_scale_new, v_packed_new, v_r_new, v_c_new
