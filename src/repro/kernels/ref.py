"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth the kernels must match bit-for-bit (codes) /
allclose (floats). They intentionally mirror the kernel's *structured* layout:
2-d tensors with the last dim a multiple of 256 (so nibble pairs and B128
blocks never straddle tiles), m quantized B128/<table> per row-major block,
v quantized rank-1/<table> with externally supplied new scales.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

__all__ = [
    "unpack_codes",
    "pack_codes",
    "dequant_blockwise",
    "dequant_rank1",
    "encode_table",
    "fused_adamw4_reference",
]


def unpack_codes(packed: jnp.ndarray) -> jnp.ndarray:
    """(R, C/2) uint8 -> (R, C) uint8 codes (low nibble first)."""
    lo = packed & jnp.uint8(0x0F)
    hi = (packed >> 4) & jnp.uint8(0x0F)
    return jnp.stack([lo, hi], axis=-1).reshape(packed.shape[0], -1)


def pack_codes(codes: jnp.ndarray) -> jnp.ndarray:
    """(R, C) uint8 -> (R, C/2) uint8 (low nibble first)."""
    pairs = codes.reshape(codes.shape[0], -1, 2)
    return (pairs[..., 0] | (pairs[..., 1] << 4)).astype(jnp.uint8)


def decode_table(codes: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table, codes.astype(jnp.int32), axis=0)


def encode_table(n: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    mids = (table[1:] + table[:-1]) / 2.0
    return jnp.sum(n[..., None] > mids, axis=-1).astype(jnp.uint8)


def _guard(s: jnp.ndarray) -> jnp.ndarray:
    return jnp.where(s > 0, s, jnp.ones_like(s))


def dequant_blockwise(
    packed: jnp.ndarray, scale: jnp.ndarray, table: jnp.ndarray, block: int = 128
) -> jnp.ndarray:
    """packed (R, C/2), scale (R, C/block) -> (R, C) fp32."""
    codes = unpack_codes(packed)
    vals = decode_table(codes, table)
    R, C = vals.shape
    per_elem = jnp.repeat(scale, block, axis=1)
    return vals * per_elem


def dequant_rank1(
    packed: jnp.ndarray, r: jnp.ndarray, c: jnp.ndarray, table: jnp.ndarray
) -> jnp.ndarray:
    """packed (R, C/2), r (R,), c (C,) -> (R, C) fp32."""
    codes = unpack_codes(packed)
    vals = decode_table(codes, table)
    scale = _guard(jnp.minimum(r[:, None], c[None, :]))
    return vals * scale


def quant_blockwise(
    x: jnp.ndarray, table: jnp.ndarray, block: int = 128
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(R, C) -> packed (R, C/2), scale (R, C/block)."""
    R, C = x.shape
    blocks = x.reshape(R, C // block, block)
    scale = _guard(jnp.max(jnp.abs(blocks), axis=-1))  # (R, C/block)
    n = (blocks / scale[..., None]).reshape(R, C)
    codes = encode_table(n, table)
    return pack_codes(codes), scale


def quant_rank1_given_scales(
    x: jnp.ndarray, r: jnp.ndarray, c: jnp.ndarray, table: jnp.ndarray
) -> jnp.ndarray:
    """(R, C) with given new rank-1 stats -> packed codes (R, C/2)."""
    scale = _guard(jnp.minimum(r[:, None], c[None, :]))
    codes = encode_table(x / scale, table)
    return pack_codes(codes)


def fused_adamw4_reference(
    w: jnp.ndarray,          # (R, C) param
    g: jnp.ndarray,          # (R, C) grad
    m_packed: jnp.ndarray,   # (R, C/2)
    m_scale: jnp.ndarray,    # (R, C/128)
    v_packed: jnp.ndarray,   # (R, C/2)
    v_r: jnp.ndarray,        # (R,)
    v_c: jnp.ndarray,        # (C,)
    m_table: jnp.ndarray,    # (16,) signed DE
    v_table: jnp.ndarray,    # (16,) unsigned Linear
    lr: jnp.ndarray,
    b1: float,
    b2: float,
    eps: float,
    weight_decay: float,
    bc1: jnp.ndarray,
    bc2: jnp.ndarray,
):
    """Oracle for the fused kernel: dequant -> AdamW (Eq. 1) -> requant.

    Returns (w_new, m_packed_new, m_scale_new, v_packed_new, v_r_new, v_c_new).
    New rank-1 scales are row/col maxes of the updated v (the kernel receives
    them precomputed — the two-pass structure described in DESIGN.md §3).
    """
    g32 = g.astype(jnp.float32)
    m = dequant_blockwise(m_packed, m_scale, m_table)
    v = dequant_rank1(v_packed, v_r, v_c, v_table)

    m_new = b1 * m + (1.0 - b1) * g32
    v_new = b2 * v + (1.0 - b2) * g32 * g32

    u = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
    w_new = (w.astype(jnp.float32) - lr * (u + weight_decay * w.astype(jnp.float32))).astype(w.dtype)

    m_packed_new, m_scale_new = quant_blockwise(m_new, m_table)
    v_r_new = jnp.max(v_new, axis=1)
    v_c_new = jnp.max(v_new, axis=0)
    v_packed_new = quant_rank1_given_scales(v_new, v_r_new, v_c_new, v_table)
    return w_new, m_packed_new, m_scale_new, v_packed_new, v_r_new, v_c_new
