"""Standalone Pallas kernels: block-wise 4-bit quantize / dequantize.

Used by checkpoint compression and by the serving engine for on-the-fly
state compaction; also the simplest validation target for the shared
decode/encode/pack primitives reused by the fused AdamW kernel.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.adamw4bit import (_decode16, _encode16, _guard, _pack,
                                     _unpack, pick_tile_c, pick_tile_r)

__all__ = ["quantize_blockwise_4bit", "dequantize_blockwise_4bit"]

_BLOCK = 128


def _quant_kernel(x_ref, table_ref, packed_ref, scale_ref, *, num_points: int):
    x = x_ref[...].astype(jnp.float32)
    tr, tc = x.shape
    blocks = x.reshape(tr, tc // _BLOCK, _BLOCK)
    scale = _guard(jnp.max(jnp.abs(blocks), axis=-1))
    scale_ref[...] = scale
    n = (blocks / scale[..., None]).reshape(tr, tc)
    packed_ref[...] = _pack(_encode16(n, table_ref, num_points))


def _dequant_kernel(packed_ref, scale_ref, table_ref, x_ref):
    codes = _unpack(packed_ref[...])
    vals = _decode16(codes, table_ref)
    x_ref[...] = vals * jnp.repeat(scale_ref[...], _BLOCK, axis=1)


def _pad16(t):
    t = t.astype(jnp.float32)
    return jnp.pad(t, (0, 16 - t.shape[0])).reshape(1, 16)


@functools.partial(jax.jit, static_argnames=("interpret", "tile_r", "tile_c"))
def quantize_blockwise_4bit(
    x: jnp.ndarray,
    table: jnp.ndarray,
    *,
    interpret: bool = False,
    tile_r: int = 128,
    tile_c: int = 512,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    R, C = x.shape
    tr, tc = pick_tile_r(R, tile_r), pick_tile_c(C, tile_c)
    assert R % tr == 0 and C % tc == 0 and tc % 256 == 0, (R, C, tr, tc)
    kernel = functools.partial(_quant_kernel, num_points=int(table.shape[0]))
    return pl.pallas_call(
        kernel,
        grid=(R // tr, C // tc),
        in_specs=[
            pl.BlockSpec((tr, tc), lambda i, j: (i, j)),
            pl.BlockSpec((1, 16), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tr, tc // 2), lambda i, j: (i, j)),
            pl.BlockSpec((tr, tc // _BLOCK), lambda i, j: (i, j)),
        ],
        out_shape=(
            jax.ShapeDtypeStruct((R, C // 2), jnp.uint8),
            jax.ShapeDtypeStruct((R, C // _BLOCK), jnp.float32),
        ),
        interpret=interpret,
    )(x, _pad16(table))


@functools.partial(jax.jit, static_argnames=("interpret", "tile_r", "tile_c"))
def dequantize_blockwise_4bit(
    packed: jnp.ndarray,
    scale: jnp.ndarray,
    table: jnp.ndarray,
    *,
    interpret: bool = False,
    tile_r: int = 128,
    tile_c: int = 512,
) -> jnp.ndarray:
    R, Ch = packed.shape
    C = Ch * 2
    tr, tc = pick_tile_r(R, tile_r), pick_tile_c(C, tile_c)
    assert R % tr == 0 and C % tc == 0 and tc % 256 == 0, (R, C, tr, tc)
    return pl.pallas_call(
        _dequant_kernel,
        grid=(R // tr, C // tc),
        in_specs=[
            pl.BlockSpec((tr, tc // 2), lambda i, j: (i, j)),
            pl.BlockSpec((tr, tc // _BLOCK), lambda i, j: (i, j)),
            pl.BlockSpec((1, 16), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tr, tc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, C), jnp.float32),
        interpret=interpret,
    )(packed, scale, _pad16(table))
