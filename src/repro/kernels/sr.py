"""Counter-based randomness for in-kernel stochastic rounding.

The fused AdamW kernel requantizes moments *inside* the Pallas kernel, so the
stochastic-rounding noise must be generated in-kernel too — materializing an
fp32 uniform tensor in HBM would forfeit the memory saving the fusion exists
for.  ``pltpu.prng_*`` has no interpret-mode lowering, so the kernel instead
runs Threefry-2x32 (the same PRNG family JAX's keys use) expressed in plain
uint32 jnp ops: add/xor/shift lower both in compiled TPU Pallas and in
interpret mode, and — crucially — produce bit-identical streams in the kernel
and in the pure-jnp reference oracle, so the SR path is testable bit-for-bit,
not just statistically.

Stream derivation (see docs/kernels.md):

    per-leaf key   = fold_in(step key, leaf index)        (compressed())
    per-slice key  = fold_in(leaf key, slice index)       (ops.py, one 2-d
                                                           slice per leading
                                                           dim of the leaf)
    per-element    = threefry2x32(key_words(slice key),
                     random bits    counter0 = row * C + col,
                                    counter1 = stream id (0 = m, 1 = v))

Because the counter is the *global element index within the slice*, the bits
an element sees are independent of the kernel tiling and of the mesh layout —
retiling or resharding replays the identical noise.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "threefry2x32",
    "uniform_from_bits",
    "key_words",
    "key_rows",
    "tensor_uniforms",
    "STREAM_M",
    "STREAM_V",
    "STREAM_GRAD",
    "STREAM_SAMPLE",
]

# Stream ids separating the two moments' noise within one (key, element) pair.
STREAM_M = 0
STREAM_V = 1
# Gradient-transport quantization (repro.comms) — its own counter stream so
# the wire noise never collides with either moment's even under a shared key.
STREAM_GRAD = 2
# Token sampling in the serving engine (repro.serve.sampling): per-request
# Gumbel noise, counter = generated-token index, so a request's sampled
# stream is independent of which cache slot it lands in.
STREAM_SAMPLE = 3

_PARITY = np.uint32(0x1BD11BDA)  # Threefry key-schedule parity constant
_ROT = (13, 15, 26, 6, 17, 29, 16, 24)


def _rotl(x: jnp.ndarray, r: int) -> jnp.ndarray:
    return jax.lax.shift_left(x, jnp.uint32(r)) | jax.lax.shift_right_logical(
        x, jnp.uint32(32 - r)
    )


def threefry2x32(k0, k1, c0, c1) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Threefry-2x32 (20 rounds, Random123/JAX-compatible).

    ``k0/k1`` are uint32 key words, ``c0/c1`` uint32 counters (arrays or
    scalars; standard broadcasting).  Returns the two output words.  Matches
    ``jax.extend.random.threefry_2x32`` bit-for-bit (test-enforced), and uses
    only uint32 add/xor/shift — safe inside Pallas TPU kernels and in
    interpret mode.
    """
    k0 = jnp.asarray(k0, jnp.uint32)
    k1 = jnp.asarray(k1, jnp.uint32)
    ks = (k0, k1, k0 ^ k1 ^ _PARITY)
    x0 = jnp.asarray(c0, jnp.uint32) + k0
    x1 = jnp.asarray(c1, jnp.uint32) + k1
    for group in range(5):
        rots = _ROT[0:4] if group % 2 == 0 else _ROT[4:8]
        for r in rots:
            x0 = x0 + x1
            x1 = _rotl(x1, r)
            x1 = x1 ^ x0
        x0 = x0 + ks[(group + 1) % 3]
        x1 = x1 + ks[(group + 2) % 3] + jnp.uint32(group + 1)
    return x0, x1


def uniform_from_bits(bits: jnp.ndarray) -> jnp.ndarray:
    """uint32 -> fp32 uniform in [0, 1) using the top 24 bits (exact in fp32)."""
    return jax.lax.shift_right_logical(bits, jnp.uint32(8)).astype(jnp.float32) * (
        1.0 / (1 << 24)
    )


def key_words(key: jax.Array) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The two uint32 words of a JAX PRNG key (typed or raw uint32 layout)."""
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        data = jax.random.key_data(key)
    else:
        data = key
    data = data.astype(jnp.uint32).reshape(-1)
    return data[-2], data[-1]


def key_rows(keys: jax.Array) -> jnp.ndarray:
    """(L,)-batched PRNG keys -> (L, 2) uint32 seed rows.

    The batched twin of ``key_words`` (same last-two-words layout per key), in
    the shape the 3-d-grid fused kernel consumes: row ``l`` seeds slice ``l``.
    Accepts typed keys (e.g. from a vmapped ``fold_in``) or raw uint32
    ``(L, 2)`` layouts.
    """
    if jnp.issubdtype(keys.dtype, jax.dtypes.prng_key):
        data = jax.random.key_data(keys)
    else:
        data = keys
    data = data.astype(jnp.uint32)
    return data.reshape(data.shape[0], -1)[:, -2:]


def element_uniforms(
    k0, k1, shape: Tuple[int, int], stream: int
) -> jnp.ndarray:
    """Per-element uniforms for a 2-d (R, C) slice, counter = r * C + c.

    The pure-jnp twin of the kernel's in-tile derivation (same bits for the
    same key/stream/element — bit-exact kernel-vs-reference SR).
    """
    R, C = shape
    linear = jnp.arange(R * C, dtype=jnp.uint32).reshape(R, C)
    bits, _ = threefry2x32(k0, k1, linear, jnp.uint32(stream))
    return uniform_from_bits(bits)


def tensor_uniforms(key: jax.Array, shape: Tuple[int, ...], stream: int) -> jnp.ndarray:
    """Per-element uniforms for an arbitrary-rank tensor, counter = the
    flattened global element index.

    The any-ndim sibling of ``element_uniforms`` taking a PRNG key directly.
    Unlike ``jax.random.uniform`` under the default (non-partitionable)
    Threefry lowering — whose draws depend on how the output is sharded —
    the counter-based derivation yields the same bits for the same
    (key, element) on every mesh layout, which is what lets quantized
    gradient transport (``repro.comms``) promise bit-identical results
    across elastic mesh restarts.
    """
    k0, k1 = key_words(key)
    n = 1
    for d in shape:
        n *= int(d)
    linear = jnp.arange(n, dtype=jnp.uint32).reshape(shape)
    bits, _ = threefry2x32(k0, k1, linear, jnp.uint32(stream))
    return uniform_from_bits(bits)
