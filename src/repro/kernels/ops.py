"""jit'd wrappers around the Pallas kernels with backend dispatch.

`fused_adamw4_leaf` is the integration point used by
``FusedAdamWRoute`` (``repro.core.optimizers.transform``): it takes a
(param, grad, QuantizedTensor m, QuantizedTensor v) leaf and returns the
updated triple, computing the new rank-1 scales in a prepass and running the
elementwise dequant->AdamW->requant in ONE Pallas launch.

Leaves may have stacked leading dims (the model stores per-layer-group
tensors ``(L, d_in, d_out)``): the leaf is viewed as L 2-d slices, all
updated by a single ``pallas_call`` with a 3-d grid ``(L, R/TR, C/TC)`` whose
outer dim walks the slices — no per-slice Python loop, so a 24-deep layer
stack costs one launch and traces O(1) jaxpr equations (test-enforced in
``tests/test_kernel_fusion.py``).  The rank-1 v scales stay *global* per-dim
stats (matching ``rank1_normalize``); per slice, the leading-dim stats fold
into the row stat — ``min(lead_l, r_i, c_j) == min(min(lead_l, r_i), c_j)``
— so each slice is exactly the kernel's ``min(row, col)`` contract, with
per-slice row stats ``(L, R)`` and shared col stats ``(C,)``.

Stochastic rounding: the per-leaf SR key (handed down from ``compressed()``'s
``fold_in(step key, leaf index)`` stream) derives one key per slice with a
single vmapped ``fold_in(leaf_key, slice index)``; the resulting ``(L, 2)``
seed rows feed the kernel's outer grid dim.  The kernel (and the reference
oracle) expand each row to per-element Threefry noise counter-keyed on the
element's slice-local index, so the noise is independent of tiling, mesh
layout, and launch batching, and identical across backends — the 3-d-grid
launch is bit-identical to the historical per-slice launches.

Backend selection: on TPU the kernel runs compiled; elsewhere it runs in
``interpret=True`` mode (Python emulation — correct but slow), unless
``REPRO_KERNEL_BACKEND=ref`` routes to the pure-jnp reference instead
(the default off-TPU — fast on CPU, bit-identical to the kernel).  The ref
path vmaps the per-slice oracle over the leading dim, so it also traces O(1)
equations regardless of L.
"""

from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.extend import core as jex_core

from repro.core.quantizer import QuantizedTensor
from repro.kernels import ref
from repro.kernels.adamw4bit import fused_adamw4
from repro.kernels.sr import key_rows

__all__ = [
    "fused_adamw4_leaf",
    "kernel_backend",
    "count_pallas_calls",
    "jaxpr_eqn_count",
]

_BLOCK = 128


def kernel_backend() -> str:
    """'tpu' -> compiled pallas; 'interpret' -> pallas interpret mode;
    'ref' -> pure-jnp oracle (fast on CPU)."""
    override = os.environ.get("REPRO_KERNEL_BACKEND")
    if override:
        return override
    platform = jax.default_backend()
    if platform == "tpu":
        return "tpu"
    return "ref"


def _sub_jaxprs(eqn):
    """Nested jaxprs of an equation (pjit/scan/cond/custom_* bodies)."""
    for v in eqn.params.values():
        vs = v if isinstance(v, (list, tuple)) else (v,)
        for x in vs:
            if isinstance(x, jex_core.ClosedJaxpr):
                yield x.jaxpr
            elif isinstance(x, jex_core.Jaxpr):
                yield x


def count_pallas_calls(jaxpr) -> int:
    """Number of ``pallas_call`` equations anywhere in ``jaxpr`` (recursive).

    The launch-count invariant's measuring stick: an ndim>=3 leaf through
    ``fused_adamw4_leaf`` must trace exactly ONE (CI trace-size gate).
    Accepts a ``Jaxpr`` or ``ClosedJaxpr``.
    """
    if isinstance(jaxpr, jex_core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            n += 1
        for sub in _sub_jaxprs(eqn):
            n += count_pallas_calls(sub)
    return n


def jaxpr_eqn_count(jaxpr) -> int:
    """Total equation count including nested jaxprs — the trace-size metric
    the CI gate compares across L to prove the ref path does not unroll."""
    if isinstance(jaxpr, jex_core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    n = 0
    for eqn in jaxpr.eqns:
        n += 1
        for sub in _sub_jaxprs(eqn):
            n += jaxpr_eqn_count(sub)
    return n


def _rank1_slice_stats(
    stats: Tuple[jnp.ndarray, ...], shape: Tuple[int, ...]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-dim rank-1 stats -> per-slice (L, R) row stats + shared (C,) cols.

    Leading-dim stats fold into the row stat (min is associative), so each
    2-d slice sees the same per-element scale ``rank1_denorm`` would build.
    """
    lead_shape = shape[:-2]
    row, col = stats[-2], stats[-1]
    if not lead_shape:
        return row[None, :], col
    lead = None
    for r, st in enumerate(stats[:-2]):
        view = [1] * len(lead_shape)
        view[r] = lead_shape[r]
        b = st.reshape(view)
        lead = b if lead is None else jnp.minimum(lead, b)
    lead = jnp.broadcast_to(lead, lead_shape).reshape(-1)  # (L,)
    return jnp.minimum(lead[:, None], row[None, :]), col


def _rank1_new_stats(v_new: jnp.ndarray) -> Tuple[jnp.ndarray, ...]:
    """Per-dim absmax stats of the updated v (rank1_normalize's layout).
    v_new is nonnegative, so plain maxes are absmaxes."""
    nd = v_new.ndim
    return tuple(
        jnp.max(v_new, axis=tuple(i for i in range(nd) if i != r))
        for r in range(nd)
    )


def fused_adamw4_leaf(
    p: jnp.ndarray,
    g: jnp.ndarray,
    m_s: QuantizedTensor,
    v_s: QuantizedTensor,
    lr: jnp.ndarray,
    b1: float,
    b2: float,
    eps: float,
    weight_decay: float,
    bc1: jnp.ndarray,
    bc2: jnp.ndarray,
    key: Optional[jax.Array] = None,
) -> Tuple[jnp.ndarray, QuantizedTensor, QuantizedTensor]:
    """One fused-kernel AdamW step for an ndim>=2 leaf with 4-bit m (B128)
    and 4-bit v (rank-1) — one Pallas launch regardless of stacked leading
    dims.  ``key`` activates in-kernel stochastic rounding when the configs
    request it (caller guards eligibility; no key => RTN, mirroring
    ``quantize()``'s fallback)."""
    shape = p.shape
    R, C = shape[-2], shape[-1]
    L = p.size // (R * C)
    use_sr = bool(m_s.config.stochastic_rounding) and key is not None

    m_table = m_s.config.table()
    v_table = v_s.config.table()

    p3 = p.reshape(L, R, C)
    g3 = g.astype(jnp.float32).reshape(L, R, C)
    m_packed = m_s.codes.reshape(L, R, C // 2)
    m_scale = m_s.scales[0].reshape(L, R, C // _BLOCK)
    v_packed = v_s.codes.reshape(L, R, C // 2)
    v_r, v_c = _rank1_slice_stats(v_s.scales, shape)  # (L, R), (C,)

    # Prepass: global rank-1 stats of the UPDATED v, via batched dequant
    # (XLA fuses dequant+max; nothing fp32 is materialized in HBM on the
    # compiled path).
    v_old = jax.vmap(ref.dequant_rank1, in_axes=(0, 0, None, None))(
        v_packed, v_r, v_c, v_table
    )
    v_new_expr = b2 * v_old + (1.0 - b2) * g3 * g3
    new_stats = _rank1_new_stats(v_new_expr.reshape(shape))
    v_r_new, v_c_new = _rank1_slice_stats(new_stats, shape)

    # One vmapped fold_in derives every slice key; the (L, 2) seed rows feed
    # the kernel's outer grid dim (row l seeds slice l).
    seed_rows = (
        key_rows(
            jax.vmap(jax.random.fold_in, in_axes=(None, 0))(key, jnp.arange(L))
        )
        if use_sr
        else None
    )

    backend = kernel_backend()
    if backend == "ref":
        # vmap the per-slice oracle: O(1) trace regardless of L.
        if use_sr:
            def _slice(w, g2, mp, ms, vp, vr, vrn, sd):
                return ref.fused_adamw4_sr_reference(
                    w, g2, mp, ms, vp, vr, v_c, m_table, v_table,
                    lr, b1, b2, eps, weight_decay, bc1, bc2,
                    sd, vrn, v_c_new,
                )

            w3, mp3, ms3, vp3, _, _ = jax.vmap(_slice)(
                p3, g3, m_packed, m_scale, v_packed, v_r, v_r_new, seed_rows
            )
        else:
            def _slice(w, g2, mp, ms, vp, vr, vrn):
                return ref.fused_adamw4_reference(
                    w, g2, mp, ms, vp, vr, v_c, m_table, v_table,
                    lr, b1, b2, eps, weight_decay, bc1, bc2,
                    vrn, v_c_new,
                )

            w3, mp3, ms3, vp3, _, _ = jax.vmap(_slice)(
                p3, g3, m_packed, m_scale, v_packed, v_r, v_r_new
            )
    else:
        # One 3-d-grid pallas_call covers every slice.
        w3, mp3, ms3, vp3 = fused_adamw4(
            p3, g3, m_packed, m_scale, v_packed,
            v_r, v_c, v_r_new, v_c_new,
            m_table, v_table, lr, bc1, bc2, seed_rows,
            b1=b1, b2=b2, eps=eps, weight_decay=weight_decay,
            interpret=(backend != "tpu"), use_sr=use_sr,
        )

    w_new = w3.reshape(shape).astype(p.dtype)
    m_codes = mp3.reshape(m_s.codes.shape)
    m_scales = ms3.reshape(m_s.scales[0].shape)
    v_codes = vp3.reshape(v_s.codes.shape)

    m2 = QuantizedTensor(m_codes, (m_scales,), m_s.shape, m_s.config)
    v2 = QuantizedTensor(v_codes, new_stats, v_s.shape, v_s.config)
    return w_new, m2, v2
