"""jit'd wrappers around the Pallas kernels with backend dispatch.

`fused_adamw4_leaf` is the integration point used by
``repro.core.optimizers.adamw.quantized_adamw(use_kernel=True)``: it takes a
(param, grad, QuantizedTensor m, QuantizedTensor v) leaf and returns the
updated triple, computing the new rank-1 scales in a prepass and running the
elementwise dequant->AdamW->requant in one Pallas kernel.

Backend selection: on TPU the kernel runs compiled; elsewhere it runs in
``interpret=True`` mode (Python emulation — correct but slow), unless
``REPRO_FORCE_INTERPRET=0`` routes to the pure-jnp reference instead.
"""

from __future__ import annotations

import os
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.quantizer import QuantizedTensor
from repro.kernels import ref
from repro.kernels.adamw4bit import fused_adamw4

__all__ = ["fused_adamw4_leaf", "kernel_backend"]


def kernel_backend() -> str:
    """'tpu' -> compiled pallas; 'interpret' -> pallas interpret mode;
    'ref' -> pure-jnp oracle (fast on CPU)."""
    override = os.environ.get("REPRO_KERNEL_BACKEND")
    if override:
        return override
    platform = jax.default_backend()
    if platform == "tpu":
        return "tpu"
    return "ref"


def _structured_scales(m_s: QuantizedTensor) -> jnp.ndarray:
    """Flat (nb,) B128 scales -> structured (R, C/128)."""
    R, C = m_s.shape
    return m_s.scales[0].reshape(R, C // 128)



def fused_adamw4_leaf(
    p: jnp.ndarray,
    g: jnp.ndarray,
    m_s: QuantizedTensor,
    v_s: QuantizedTensor,
    lr: jnp.ndarray,
    b1: float,
    b2: float,
    eps: float,
    weight_decay: float,
    bc1: jnp.ndarray,
    bc2: jnp.ndarray,
) -> Tuple[jnp.ndarray, QuantizedTensor, QuantizedTensor]:
    """One fused-kernel AdamW step for a 2-d leaf with 4-bit m (B128) and
    4-bit v (rank-1). Falls back to the reference composition for layouts
    the kernel does not cover (caller guards eligibility)."""
    R, C = p.shape
    m_table = m_s.config.table()
    v_table = v_s.config.table()
    g32 = g.astype(jnp.float32)

    # Prepass: rank-1 stats of the UPDATED v (XLA fuses dequant+max).
    v_old = ref.dequant_rank1(v_s.codes, v_s.scales[0], v_s.scales[1], v_table)
    v_new_expr = b2 * v_old + (1.0 - b2) * g32 * g32
    v_r_new = jnp.max(v_new_expr, axis=1)
    v_c_new = jnp.max(v_new_expr, axis=0)

    backend = kernel_backend()
    if backend == "ref":
        w_new, m_packed, m_scale, v_packed, v_r, v_c = ref.fused_adamw4_reference(
            p, g, m_s.codes, _structured_scales(m_s), v_s.codes,
            v_s.scales[0], v_s.scales[1], m_table, v_table,
            lr, b1, b2, eps, weight_decay, bc1, bc2,
        )
    else:
        w_new, m_packed, m_scale, v_packed = fused_adamw4(
            p, g, m_s.codes, _structured_scales(m_s), v_s.codes,
            v_s.scales[0], v_s.scales[1], v_r_new, v_c_new,
            m_table, v_table, lr, bc1, bc2,
            b1=b1, b2=b2, eps=eps, weight_decay=weight_decay,
            interpret=(backend != "tpu"),
        )
        v_r, v_c = v_r_new, v_c_new

    m2 = QuantizedTensor(m_packed, (m_scale.reshape(-1),), m_s.shape, m_s.config)
    v2 = QuantizedTensor(v_packed, (v_r, v_c), v_s.shape, v_s.config)
    return w_new, m2, v2
