"""jit'd wrappers around the Pallas kernels with backend dispatch.

`fused_adamw4_leaf` is the integration point used by
``FusedAdamWRoute`` (``repro.core.optimizers.transform``): it takes a
(param, grad, QuantizedTensor m, QuantizedTensor v) leaf and returns the
updated triple, computing the new rank-1 scales in a prepass and running the
elementwise dequant->AdamW->requant in one Pallas kernel.

Leaves may have stacked leading dims (the model stores per-layer-group
tensors ``(L, d_in, d_out)``): the leaf is viewed as L independent 2-d
slices, each handed to one kernel launch.  The rank-1 v scales stay *global*
per-dim stats (matching ``rank1_normalize``); per slice, the leading-dim
stats fold into the row stat — ``min(lead_l, r_i, c_j) ==
min(min(lead_l, r_i), c_j)`` — so each slice is exactly the kernel's
``min(row, col)`` contract.

Stochastic rounding: the per-leaf SR key (handed down from ``compressed()``'s
``fold_in(step key, leaf index)`` stream) derives one key per slice via
``fold_in(leaf_key, slice index)``; the kernel (and the reference oracle)
expand it to per-element Threefry noise counter-keyed on the element index,
so the noise is independent of tiling and mesh layout and identical across
backends.

Backend selection: on TPU the kernel runs compiled; elsewhere it runs in
``interpret=True`` mode (Python emulation — correct but slow), unless
``REPRO_KERNEL_BACKEND=ref`` routes to the pure-jnp reference instead
(the default off-TPU — fast on CPU, bit-identical to the kernel).
"""

from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.quantizer import QuantizedTensor
from repro.kernels import ref
from repro.kernels.adamw4bit import fused_adamw4
from repro.kernels.sr import key_words

__all__ = ["fused_adamw4_leaf", "kernel_backend"]

_BLOCK = 128


def kernel_backend() -> str:
    """'tpu' -> compiled pallas; 'interpret' -> pallas interpret mode;
    'ref' -> pure-jnp oracle (fast on CPU)."""
    override = os.environ.get("REPRO_KERNEL_BACKEND")
    if override:
        return override
    platform = jax.default_backend()
    if platform == "tpu":
        return "tpu"
    return "ref"


def _rank1_slice_stats(
    stats: Tuple[jnp.ndarray, ...], shape: Tuple[int, ...]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-dim rank-1 stats -> per-slice (L, R) row stats + shared (C,) cols.

    Leading-dim stats fold into the row stat (min is associative), so each
    2-d slice sees the same per-element scale ``rank1_denorm`` would build.
    """
    lead_shape = shape[:-2]
    row, col = stats[-2], stats[-1]
    if not lead_shape:
        return row[None, :], col
    lead = None
    for r, st in enumerate(stats[:-2]):
        view = [1] * len(lead_shape)
        view[r] = lead_shape[r]
        b = st.reshape(view)
        lead = b if lead is None else jnp.minimum(lead, b)
    lead = jnp.broadcast_to(lead, lead_shape).reshape(-1)  # (L,)
    return jnp.minimum(lead[:, None], row[None, :]), col


def _rank1_new_stats(v_new: jnp.ndarray) -> Tuple[jnp.ndarray, ...]:
    """Per-dim absmax stats of the updated v (rank1_normalize's layout).
    v_new is nonnegative, so plain maxes are absmaxes."""
    nd = v_new.ndim
    return tuple(
        jnp.max(v_new, axis=tuple(i for i in range(nd) if i != r))
        for r in range(nd)
    )


def fused_adamw4_leaf(
    p: jnp.ndarray,
    g: jnp.ndarray,
    m_s: QuantizedTensor,
    v_s: QuantizedTensor,
    lr: jnp.ndarray,
    b1: float,
    b2: float,
    eps: float,
    weight_decay: float,
    bc1: jnp.ndarray,
    bc2: jnp.ndarray,
    key: Optional[jax.Array] = None,
) -> Tuple[jnp.ndarray, QuantizedTensor, QuantizedTensor]:
    """One fused-kernel AdamW step for an ndim>=2 leaf with 4-bit m (B128)
    and 4-bit v (rank-1).  ``key`` activates in-kernel stochastic rounding
    when the configs request it (caller guards eligibility; no key => RTN,
    mirroring ``quantize()``'s fallback)."""
    shape = p.shape
    R, C = shape[-2], shape[-1]
    L = p.size // (R * C)
    use_sr = bool(m_s.config.stochastic_rounding) and key is not None

    m_table = m_s.config.table()
    v_table = v_s.config.table()

    p3 = p.reshape(L, R, C)
    g3 = g.astype(jnp.float32).reshape(L, R, C)
    m_packed = m_s.codes.reshape(L, R, C // 2)
    m_scale = m_s.scales[0].reshape(L, R, C // _BLOCK)
    v_packed = v_s.codes.reshape(L, R, C // 2)
    v_r, v_c = _rank1_slice_stats(v_s.scales, shape)  # (L, R), (C,)

    # Prepass: global rank-1 stats of the UPDATED v (XLA fuses dequant+max;
    # nothing fp32 is materialized in HBM on the compiled path).
    v_old = jnp.stack(
        [ref.dequant_rank1(v_packed[l], v_r[l], v_c, v_table) for l in range(L)]
    )
    v_new_expr = b2 * v_old + (1.0 - b2) * g3 * g3
    new_stats = _rank1_new_stats(v_new_expr.reshape(shape))
    v_r_new, v_c_new = _rank1_slice_stats(new_stats, shape)

    slice_keys = (
        [key_words(jax.random.fold_in(key, l)) for l in range(L)]
        if use_sr
        else [None] * L
    )

    backend = kernel_backend()
    w_out, mp_out, ms_out, vp_out = [], [], [], []
    for l in range(L):
        if backend == "ref":
            if use_sr:
                k0, k1 = slice_keys[l]
                w_new, mp, ms, vp, _, _ = ref.fused_adamw4_sr_reference(
                    p3[l], g3[l], m_packed[l], m_scale[l], v_packed[l],
                    v_r[l], v_c, m_table, v_table,
                    lr, b1, b2, eps, weight_decay, bc1, bc2,
                    jnp.stack([k0, k1]), v_r_new[l], v_c_new,
                )
            else:
                w_new, mp, ms, vp, _, _ = ref.fused_adamw4_reference(
                    p3[l], g3[l], m_packed[l], m_scale[l], v_packed[l],
                    v_r[l], v_c, m_table, v_table,
                    lr, b1, b2, eps, weight_decay, bc1, bc2,
                    v_r_new[l], v_c_new,
                )
        else:
            seed = (
                jnp.stack(slice_keys[l]) if use_sr else None
            )
            w_new, mp, ms, vp = fused_adamw4(
                p3[l], g3[l], m_packed[l], m_scale[l], v_packed[l],
                v_r[l], v_c, v_r_new[l], v_c_new,
                m_table, v_table, lr, bc1, bc2, seed,
                b1=b1, b2=b2, eps=eps, weight_decay=weight_decay,
                interpret=(backend != "tpu"), use_sr=use_sr,
            )
        w_out.append(w_new)
        mp_out.append(mp)
        ms_out.append(ms)
        vp_out.append(vp)

    w_new = jnp.stack(w_out).reshape(shape).astype(p.dtype)
    m_codes = jnp.stack(mp_out).reshape(m_s.codes.shape)
    m_scales = jnp.stack(ms_out).reshape(m_s.scales[0].shape)
    v_codes = jnp.stack(vp_out).reshape(v_s.codes.shape)

    m2 = QuantizedTensor(m_codes, (m_scales,), m_s.shape, m_s.config)
    v2 = QuantizedTensor(v_codes, new_stats, v_s.shape, v_s.config)
    return w_new, m2, v2
