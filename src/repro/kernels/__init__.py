"""Pallas TPU kernels for the paper's compute hot-spot: the fused 4-bit
optimizer update (dequant -> AdamW -> requant in one VMEM-resident pass)."""
