"""Resharding checkpoint restore.

``restore_checkpoint`` dispatches on ``manifest.json["format_version"]``:
v1 dirs go through the legacy npz reader; v2 dirs are assembled shard-wise.

For v2, every target leaf is built with ``jax.make_array_from_callback``:
jax asks for exactly the regions the *current* mesh layout needs, and the
callback stitches each requested region from whatever shard layout is on
disk — intersecting the requested index ranges with the on-disk shard
ranges and copying only the overlaps out of memory-mapped shard files.  A
checkpoint saved on a 2x4 mesh restores onto 4x2, 8x1, or a single device
without any host ever materializing a full global array (for sharded
targets; a single-device target's region IS the full leaf, which is the
best any single device can do).
"""

from __future__ import annotations

import os
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.io import format as fmt
from repro.io.legacy import restore_npz

__all__ = ["restore_checkpoint"]


def _alloc_region(key: str, shape: Tuple[int, ...], dtype: np.dtype) -> np.ndarray:
    """Host buffer for ONE requested region of one leaf.  Every host-side
    restore allocation funnels through here — the gather-spy test patches
    this to prove sharded restores never build a global array."""
    return np.empty(shape, dtype)


def _open_shard(d: str, key: str, rec: Dict, dtype: np.dtype, hash_cache):
    """Memory-mapped view of one on-disk shard (validated once per shard)."""
    path = os.path.join(d, rec["file"])
    shard_shape = tuple(int(e) - int(s) for s, e in rec["index"])
    n = int(rec["nbytes"])
    expected = int(np.prod(shard_shape, dtype=np.int64)) * dtype.itemsize
    if n != expected:
        raise IOError(
            f"checkpoint corruption at {key}: shard in {rec['file']} records "
            f"{n} bytes for shape {shard_shape} ({expected} expected)"
        )
    try:
        size = os.path.getsize(path)
    except OSError as e:
        raise IOError(f"checkpoint missing shard file {rec['file']}") from e
    if size < rec["offset"] + n:
        raise IOError(
            f"checkpoint corruption at {key}: {rec['file']} truncated "
            f"({size} bytes, shard ends at {rec['offset'] + n})"
        )
    if n == 0 or shard_shape == ():
        with open(path, "rb") as f:
            f.seek(rec["offset"])
            buf = f.read(n)
        if hash_cache is not None and fmt.sha_bytes(buf) != rec["sha256"]:
            raise IOError(f"checkpoint corruption at {key} (hash mismatch)")
        return np.frombuffer(buf, dtype=dtype).reshape(shard_shape)
    mm = np.memmap(path, dtype=dtype, mode="r", offset=rec["offset"], shape=shard_shape)
    if hash_cache is not None:
        ck = (rec["file"], rec["offset"])
        if ck not in hash_cache:
            hash_cache[ck] = fmt.sha_bytes(mm.tobytes())
        if hash_cache[ck] != rec["sha256"]:
            raise IOError(f"checkpoint corruption at {key} (hash mismatch)")
    return mm


def _assemble_region(
    d: str,
    key: str,
    shape: Tuple[int, ...],
    dtype: np.dtype,
    shards: List[Dict],
    index,
    hash_cache,
) -> np.ndarray:
    """One requested region of one leaf, stitched from on-disk shards."""
    want = fmt.normalize_index(index, shape)
    region = _alloc_region(key, tuple(e - s for s, e in want), dtype)
    filled = 0
    for rec in shards:
        inter = [
            (max(ws, int(rs)), min(we, int(re_)))
            for (ws, we), (rs, re_) in zip(want, rec["index"])
        ]
        if any(s >= e for s, e in inter):
            continue  # this shard doesn't overlap the requested region
        src = _open_shard(d, key, rec, dtype, hash_cache)
        src_sl = tuple(
            slice(s - int(rs), e - int(rs))
            for (s, e), (rs, _) in zip(inter, rec["index"])
        )
        dst_sl = tuple(
            slice(s - ws, e - ws) for (s, e), (ws, _) in zip(inter, want)
        )
        region[dst_sl] = src[src_sl]
        n = 1
        for s, e in inter:
            n *= e - s
        filled += n
    if filled < region.size:
        raise IOError(
            f"checkpoint incomplete at {key}: on-disk shards cover only "
            f"{filled}/{region.size} elements of the requested region "
            "(missing host shard file?)"
        )
    return region


def _sharding_leaves(shardings, n_paths: int):
    if shardings is None:
        return None
    sh_leaves = jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
    )
    if len(sh_leaves) != n_paths:
        # tree_leaves drops None subtrees, which would silently shift
        # every later leaf onto the wrong sharding — refuse instead.
        raise ValueError(
            f"shardings tree has {len(sh_leaves)} sharding leaves but the "
            f"target has {n_paths} array leaves; shardings must mirror "
            "the target one sharding per leaf (no None placeholders)"
        )
    return sh_leaves


def _restore_sharded(
    d: str,
    manifest: Dict,
    paths: List[str],
    flat_target,
    sh_leaves,
    validate: bool,
) -> List[jax.Array]:
    shard_map = fmt.merged_shard_index(d)
    meta = {m["key"]: m for m in manifest["leaves"]}
    default = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    hash_cache: Optional[Dict] = {} if validate else None
    out = []
    for i, (key, (_, tleaf)) in enumerate(zip(paths, flat_target)):
        if key not in meta:
            raise KeyError(f"checkpoint missing leaf {key}")
        m = meta[key]
        shape = tuple(int(x) for x in m["shape"])
        dtype = fmt.dtype_from_str(m["dtype"])
        t_shape = getattr(tleaf, "shape", None)  # plain-scalar leaves have none
        if t_shape is not None and tuple(t_shape) != shape:
            raise ValueError(
                f"checkpoint leaf {key} has shape {shape}, target expects "
                f"{tuple(t_shape)}"
            )
        t_dtype = getattr(tleaf, "dtype", None)
        if t_dtype is not None and np.dtype(t_dtype) != dtype:
            # make_array_from_callback takes the callback's dtype verbatim —
            # without this check a dtype drift restores silently wrong.
            raise ValueError(
                f"checkpoint leaf {key} has dtype {dtype}, target expects "
                f"{np.dtype(t_dtype)}"
            )
        shards = shard_map.get(key, [])
        sharding = sh_leaves[i] if sh_leaves is not None else default

        def cb(index, *, _shape=shape, _dtype=dtype, _shards=shards, _key=key):
            return _assemble_region(
                d, _key, _shape, _dtype, _shards, index, hash_cache
            )

        out.append(jax.make_array_from_callback(shape, sharding, cb))
    return out


def restore_checkpoint(
    directory: str,
    target: Any,
    step: Optional[int] = None,
    shardings: Any = None,
    validate: bool = True,
) -> Tuple[Any, Dict]:
    """Restore into the structure of ``target`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings`` (same structure) places every leaf
    directly onto the current mesh — elastic restart across device counts
    and layouts, regardless of the layout the checkpoint was saved with."""
    if step is None:
        step = fmt.latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    d = fmt.step_dir(directory, step)
    manifest = fmt.read_manifest(d)

    if validate and "structure" in manifest:
        got = fmt.tree_structure_repr(target)
        if got != manifest["structure"]:
            raise ValueError(
                "checkpoint structure mismatch: the restore target's pytree "
                "does not match what was saved.\n"
                f"  saved:  {manifest['structure'][:512]}\n"
                f"  target: {got[:512]}\n"
                "If the checkpoint predates the transform-chain state layout "
                "(dict {'m','v','step'}), restore into the legacy structure "
                "and convert with migrate_legacy_state(state, tx)."
            )

    flat_target = jax.tree_util.tree_flatten_with_path(target)
    paths = [jax.tree_util.keystr(p) for p, _ in flat_target[0]]
    sh_leaves = _sharding_leaves(shardings, len(paths))

    if manifest.get("format_version", 1) < 2:
        out = restore_npz(d, manifest, paths, sh_leaves, validate)
    else:
        out = _restore_sharded(
            d, manifest, paths, flat_target[0], sh_leaves, validate
        )
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(target), out
    )
    return tree, manifest["extra"]
