"""Checkpoint I/O subsystem: sharded per-host format, async writes,
cross-mesh resharded restore.

Public API:
  * ``save_checkpoint`` / ``restore_checkpoint`` — synchronous save (v2
    sharded by default; ``fmt_version="npz"`` writes the legacy v1 format)
    and format-dispatching restore.
  * ``AsyncCheckpointWriter`` — double-buffered background writer.
  * ``CheckpointManager`` — async saves + keep_last/keep_every retention.
  * ``latest_step`` — newest *complete* step (COMMIT-validated, with
    fallback scan past crash leftovers).
"""

from repro.io.format import latest_step, list_steps, tree_structure_repr
from repro.io.manager import CheckpointManager
from repro.io.reader import restore_checkpoint
from repro.io.writer import AsyncCheckpointWriter, save_checkpoint, snapshot_tree

__all__ = [
    "save_checkpoint",
    "restore_checkpoint",
    "latest_step",
    "list_steps",
    "CheckpointManager",
    "AsyncCheckpointWriter",
    "snapshot_tree",
    "tree_structure_repr",
]
