"""Checkpoint lifecycle manager: async saves + retention/GC.

``CheckpointManager(dir, keep_last=N, keep_every=k)`` drives the sharded
async writer and, after each successful COMMIT, deletes superseded step
dirs: everything except the newest ``keep_last`` complete steps and (when
``keep_every`` is set) steps divisible by ``keep_every`` (periodic archival
anchors).  The newest complete step is never deleted, and incomplete dirs
older than it (crash leftovers — shard files without COMMIT) are swept too.
GC runs on the writer thread on process 0 only; it never races the save
that triggered it because the worker commits before collecting.
"""

from __future__ import annotations

import os
import shutil
from typing import Any, Dict, Optional

import jax

from repro.io import format as fmt
from repro.io.reader import restore_checkpoint
from repro.io.writer import AsyncCheckpointWriter

__all__ = ["CheckpointManager"]


class CheckpointManager:
    """Async keep-last / keep-every manager over the sharded v2 format.

    ``keep`` is the legacy alias for ``keep_last`` (pre-sharded API)."""

    def __init__(
        self,
        directory: str,
        keep_last: int = 3,
        keep_every: Optional[int] = None,
        keep: Optional[int] = None,
    ):
        if keep is not None:
            keep_last = keep
        self.directory = directory
        self.keep_last = max(1, int(keep_last))
        self.keep_every = int(keep_every) if keep_every else None
        self._writer = AsyncCheckpointWriter(directory, on_commit=self._gc)

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None,
             block: bool = False):
        """Blocks only on the device->host snapshot (and when two saves are
        already in flight); serialization + COMMIT happen in the background."""
        self._writer.save(step, tree, extra, block=block)

    def wait(self):
        self._writer.wait()

    def latest_step(self) -> Optional[int]:
        # Drain in-flight saves first: latest_step's crash repair must not
        # race the writer thread's final stage->step_X swap.
        self.wait()
        return fmt.latest_step(self.directory)

    def restore(self, target, step=None, shardings=None):
        self.wait()
        return restore_checkpoint(self.directory, target, step, shardings)

    def _gc(self, committed_step: Optional[int] = None):
        if jax.process_index() != 0:
            return
        # One directory scan, one completeness check per step dir (each
        # check parses that dir's manifest — with keep_every anchors the dir
        # count grows over a run's lifetime, so no second pass).
        steps: Dict[int, bool] = {}
        attempt_dirs = []
        for name in os.listdir(self.directory):
            if ".attempt_" in name:
                attempt_dirs.append(name)
                continue
            s = fmt.parse_step(name)
            if s is not None:
                steps[s] = fmt.is_complete(os.path.join(self.directory, name))
        complete = sorted(s for s, ok in steps.items() if ok)
        if committed_step is not None:
            # Steps newer than the one just committed are leftovers of an
            # abandoned timeline (a forced rewind replayed past them); left
            # in place they would pin a keep_last slot forever and a lost
            # LATEST pointer would resume from pre-rewind future state.
            for s in complete:
                if s > committed_step:
                    shutil.rmtree(
                        fmt.step_dir(self.directory, s), ignore_errors=True
                    )
            complete = [s for s in complete if s <= committed_step]
        if not complete:
            return
        newest = complete[-1]
        keep = set(complete[-self.keep_last:])
        if self.keep_every:
            keep.update(s for s in complete if s % self.keep_every == 0)
        keep.add(newest)  # the newest complete step is never collected
        for s in complete:
            if s not in keep:
                shutil.rmtree(fmt.step_dir(self.directory, s), ignore_errors=True)
        # crash leftovers: incomplete dirs older than the newest complete
        # save can never become restorable — sweep them too.  Newer
        # incomplete dirs are a save in flight; leave them alone.
        for s, ok in steps.items():
            if s < newest and not ok:
                shutil.rmtree(fmt.step_dir(self.directory, s), ignore_errors=True)
        # orphaned staging dirs (step_X.attempt_<nonce>) from crashed saves:
        # once their step has committed (or been superseded) they are dead
        for name in attempt_dirs:
            s = fmt.parse_step(name.split(".attempt_")[0])
            if s is not None and s <= newest:
                shutil.rmtree(
                    os.path.join(self.directory, name), ignore_errors=True
                )
