"""Sharded checkpoint writer: per-host shard snapshot + async background I/O.

Save never gathers: each host walks ``leaf.addressable_shards`` and copies
only the shards it owns (``replica_id == 0`` — the one canonical copy of
each distinct index) to host buffers, so the largest host-side allocation is
one device shard, never a global array.  Packed 4-bit codes, their scales,
and fp32 params all go through the same path — the quantized state stays
sharded through I/O, which is the whole point of 4-bit states at scale.

``AsyncCheckpointWriter`` double-buffers: ``save()`` blocks only on the
device->host snapshot copy, hands the buffers to a background thread for
serialization + fsync + COMMIT, and only ever blocks the train loop when a
third save arrives while two are still in flight (one writing, one queued).

The commit protocol (single-host and multi-host identical; cross-host
rendezvous rides the shared checkpoint filesystem — never a device
collective, which on this background thread could interleave with the train
step's collectives and deadlock):
  1. process 0 creates an attempt-unique staging dir
     (``step_X.attempt_<nonce>``) and advertises it through an atomically
     replaced pointer file; other hosts wait for the pointer;
  2. every host writes + fsyncs its own ``host_<p>.bin`` into the stage,
     then publishes ``index_host_<p>.json`` via temp + os.replace (the
     index's existence implies its bin is durably complete); process 0
     also writes ``manifest.json``;
  3. process 0 waits for all hosts' index files, writes the ``COMMIT``
     marker inside the stage, swaps the stage into ``step_X`` (setting an
     existing committed copy aside until the replacement is fully on disk),
     and updates the LATEST pointer.  A dir without COMMIT is incomplete
     and ignored by ``latest_step`` — a save killed mid-shard-write can
     never be restored.
"""

from __future__ import annotations

import glob
import json
import os
import queue
import shutil
import threading
import time
import uuid
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.io import format as fmt
from repro.io.legacy import save_checkpoint_npz

__all__ = ["Snapshot", "snapshot_tree", "write_snapshot", "save_checkpoint",
           "AsyncCheckpointWriter"]


def _device_to_host(key: str, shard_data) -> np.ndarray:
    """Host copy of ONE device shard.  Every device->host byte the writer
    moves funnels through here — the gather-spy test patches this to prove
    no full global array is ever materialized during a sharded save."""
    return np.ascontiguousarray(np.asarray(shard_data))


def _bytes_view(arr: np.ndarray):
    """Zero-copy byte view of a contiguous host array (serializing a shard
    must not double its memory on the writer thread); ml_dtypes arrays that
    can't export a PEP-3118 buffer fall back to one copy via tobytes()."""
    try:
        return memoryview(arr).cast("B")
    except (TypeError, BufferError, ValueError):
        return arr.tobytes()


_RENDEZVOUS_TIMEOUT_S = 600.0


def _barrier(name: str) -> None:
    """Commit-protocol phase boundary.  Deliberately NOT a device collective:
    this runs on the background writer thread, and a collective there could
    interleave with the train step's collectives and deadlock a multi-host
    run.  Cross-host rendezvous rides the shared checkpoint filesystem
    instead (``_await`` below) — the same assumption the reader's index-file
    merge already makes.  Kept as a named seam so tests can inject crashes
    at exact protocol points."""


def _await(predicate, what: str) -> None:
    """Poll the shared filesystem until ``predicate()`` holds (multi-host
    rendezvous without device collectives)."""
    deadline = time.monotonic() + _RENDEZVOUS_TIMEOUT_S
    while not predicate():
        if time.monotonic() > deadline:
            raise TimeoutError(f"checkpoint rendezvous timed out: {what}")
        time.sleep(0.05)


class _LeafSnapshot:
    __slots__ = ("key", "shape", "dtype", "shards")

    def __init__(self, key, shape, dtype, shards):
        self.key = key
        self.shape = tuple(int(d) for d in shape)
        self.dtype = np.dtype(dtype)
        # [(index ranges, host array)] — only the shards THIS host owns
        self.shards: List[Tuple[List[Tuple[int, int]], np.ndarray]] = shards


class Snapshot:
    """Host-side copy of the shards this process owns, ready to serialize."""

    def __init__(self, leaves: List[_LeafSnapshot], structure: str):
        self.leaves = leaves
        self.structure = structure


def _leaf_snapshot(key: str, leaf) -> _LeafSnapshot:
    if isinstance(leaf, jax.Array):
        shape = tuple(leaf.shape)
        shards = []
        for s in leaf.addressable_shards:
            if s.replica_id != 0:
                continue  # exactly one host writes each distinct index
            ranges = fmt.normalize_index(s.index, shape)
            shards.append((ranges, _device_to_host(key, s.data)))
        return _LeafSnapshot(key, shape, np.dtype(leaf.dtype), shards)
    arr = np.ascontiguousarray(np.asarray(leaf))
    shards = []
    if jax.process_index() == 0:  # host leaves: one full shard, one writer
        full = [(0, int(d)) for d in arr.shape]
        shards.append((full, arr))
    return _LeafSnapshot(key, arr.shape, arr.dtype, shards)


def snapshot_tree(tree: Any) -> Snapshot:
    """Blocking part of a save: device->host copies of owned shards only."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    leaves = [
        _leaf_snapshot(jax.tree_util.keystr(path), leaf) for path, leaf in flat
    ]
    return Snapshot(leaves, fmt.tree_structure_repr(tree))


def _fsync_write_json(path: str, obj) -> None:
    """Durable JSON whose *existence* implies complete content: write to a
    temp name, fsync, then os.replace into place."""
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def write_snapshot(
    directory: str, step: int, snap: Snapshot, extra: Optional[Dict] = None
) -> str:
    """Serialize a snapshot: shard file + index per host, manifest + COMMIT
    from process 0.  Safe to run on a background thread (touches no device).

    Stage-and-swap: everything is written into an attempt-unique staging dir
    (``step_X.attempt_<nonce>``, advertised to the other hosts through an
    atomically-replaced pointer file), and only after COMMIT lands inside is
    the staging dir swapped into ``step_X``.  Consequences: no host ever
    writes into a directory another process might clear (a host acting on a
    stale attempt pointer can only cause a rendezvous timeout, never a
    mixed-attempt commit), and an existing committed copy of the step stays
    durable on disk for the whole serialization — the vulnerable window is
    the instant between the two final renames, which
    ``repair_interrupted_resaves`` covers."""
    os.makedirs(directory, exist_ok=True)
    final = fmt.step_dir(directory, step)
    backup = final + ".replaced"  # no step_* match — invisible to list_steps
    p = jax.process_index()
    nprocs = jax.process_count()
    ptr = os.path.join(directory, f".attempt_step_{step:08d}")
    if p == 0:
        # purge leftovers of crashed attempts at this step BEFORE advertising
        # a new stage: a host that latched onto a stale pointer/stage would
        # otherwise starve this save's index rendezvous into its timeout
        if os.path.exists(ptr):
            os.remove(ptr)
        for stale in glob.glob(glob.escape(final) + ".attempt_*"):
            shutil.rmtree(stale, ignore_errors=True)
        stage = final + f".attempt_{uuid.uuid4().hex[:8]}"
        os.makedirs(stage)
        if nprocs > 1:
            tmp = ptr + ".tmp"
            with open(tmp, "w") as f:
                f.write(os.path.basename(stage))
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, ptr)
    else:

        def _resolve():
            try:
                with open(ptr) as f:
                    name = f.read().strip()
            except OSError:
                return None
            s = os.path.join(directory, name)
            return s if os.path.isdir(s) else None

        _await(lambda: _resolve() is not None, f"stage dir for step {step}")
        stage = _resolve()
    _barrier(f"ckpt_prepare_{step}")

    offset = 0
    index: Dict[str, Any] = {"process": p, "shards": {}}
    with open(os.path.join(stage, fmt.shard_file(p)), "wb") as f:
        for leaf in snap.leaves:
            recs = []
            for ranges, arr in leaf.shards:
                buf = _bytes_view(arr)  # len(buf) == nbytes for both branches
                f.write(buf)
                recs.append(
                    {
                        "offset": offset,
                        "nbytes": len(buf),
                        "index": [list(r) for r in ranges],
                        "sha256": fmt.sha_bytes(buf),
                    }
                )
                offset += len(buf)
            if recs:
                index["shards"][leaf.key] = recs
        f.flush()
        os.fsync(f.fileno())
    # index lands AFTER its bin is fsynced, via os.replace: once process 0
    # can see it, this host's shard bytes are durably complete
    _fsync_write_json(os.path.join(stage, fmt.index_file(p)), index)

    if p == 0:
        manifest = {
            "format_version": fmt.FORMAT_VERSION,
            "step": step,
            "extra": extra or {},
            "structure": snap.structure,
            "num_hosts": nprocs,
            "leaves": [
                {
                    "key": leaf.key,
                    "shape": list(leaf.shape),
                    "dtype": str(leaf.dtype),
                }
                for leaf in snap.leaves
            ],
        }
        _fsync_write_json(os.path.join(stage, fmt.MANIFEST), manifest)

    _barrier(f"ckpt_written_{step}")
    if p != 0:
        # Success on this host must imply durability: wait until process 0
        # has swapped OUR stage into place (the stage name vanishes exactly
        # at the swap) and the committed step is visible, so wait()/
        # save(block=True) mean the same thing on every host.
        _await(
            lambda: not os.path.isdir(stage)
            and os.path.exists(os.path.join(final, fmt.COMMIT)),
            f"commit of step {step}",
        )
        return final
    if nprocs > 1:
        _await(
            lambda: len(
                glob.glob(os.path.join(glob.escape(stage), "index_host_*.json"))
            )
            >= nprocs,
            f"all {nprocs} hosts' index files for step {step}",
        )
    with open(os.path.join(stage, fmt.COMMIT), "w") as f:
        f.write(f"step {step}\n")
        f.flush()
        os.fsync(f.fileno())
    # swap into place; an existing committed copy stays durable until the
    # replacement (COMMIT included) is fully on disk.  Serialized against
    # repair_interrupted_resaves, which could otherwise rename the backup
    # back into place between our two renames.
    with fmt.swap_lock:
        if os.path.exists(final):
            if fmt.is_complete(final):
                if os.path.exists(backup):
                    shutil.rmtree(backup)
                os.rename(final, backup)
            else:
                shutil.rmtree(final)  # crash leftover
        os.rename(stage, final)
        fmt.write_latest(directory, step)
        if os.path.exists(backup):
            shutil.rmtree(backup, ignore_errors=True)
    if nprocs > 1:
        try:
            os.remove(ptr)
        except OSError:
            pass
    return final


def save_checkpoint(
    directory: str,
    step: int,
    tree: Any,
    extra: Optional[Dict] = None,
    *,
    fmt_version: str = "sharded",
) -> str:
    """Synchronous save. ``fmt_version="sharded"`` (default) writes the v2
    per-host shard format; ``"npz"`` writes the legacy v1 single-file format
    (gather-to-host — only for migration tooling and format tests)."""
    if fmt_version == "npz":
        return save_checkpoint_npz(directory, step, tree, extra)
    return write_snapshot(directory, step, snapshot_tree(tree), extra)


class AsyncCheckpointWriter:
    """Double-buffered background writer.

    ``save()`` = snapshot (blocking, device->host only) + enqueue; a single
    worker thread serializes in save order so LATEST always advances
    monotonically.  At most two snapshots are in flight (one being written,
    one queued): the train loop only stalls when it laps the writer twice.
    Worker errors surface on the next ``save()``/``wait()``.
    """

    def __init__(self, directory: str, on_commit: Optional[Callable[[int], None]] = None):
        self.directory = directory
        self._on_commit = on_commit
        self._queue: "queue.Queue" = queue.Queue()
        self._slots = threading.Semaphore(2)  # the two buffers
        self._error: Optional[BaseException] = None
        self._thread: Optional[threading.Thread] = None

    def _raise_pending(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _ensure_thread(self):
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(
                target=self._worker, name="ckpt-writer", daemon=True
            )
            self._thread.start()

    def _worker(self):
        while True:
            step, snap, extra = self._queue.get()
            try:
                write_snapshot(self.directory, step, snap, extra)
                try:
                    if self._on_commit is not None:
                        self._on_commit(step)
                except BaseException as e:
                    # The save IS durable (COMMIT landed); a failed GC/
                    # retention pass must not report it as failed.
                    import warnings

                    warnings.warn(f"checkpoint post-commit hook failed: {e!r}")
            except BaseException as e:  # surfaced on next save()/wait()
                if self._error is None:  # first failure wins
                    self._error = e
            finally:
                self._slots.release()
                self._queue.task_done()

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None,
             block: bool = False):
        self._raise_pending()
        self._ensure_thread()
        self._slots.acquire()  # wait only if two saves are already in flight
        try:
            snap = snapshot_tree(tree)  # the only device-blocking work
        except BaseException:
            self._slots.release()  # failed snapshot must not leak its buffer
            raise
        self._queue.put((step, snap, extra))
        if block:
            self.wait()

    def wait(self):
        self._queue.join()
        self._raise_pending()
