"""Checkpoint format v2: per-host shard files + global manifest + COMMIT.

On-disk layout of one step (format_version 2):

    <dir>/step_00000100/
        host_00000.bin          # this host's shard bytes, concatenated
        host_00001.bin          # (one per host; single-host runs have one)
        index_host_00000.json   # per-shard {leaf key, offset, nbytes,
                                #   index ranges, sha256} for host 0's file
        index_host_00001.json
        manifest.json           # global metadata: step, extra, structure,
                                #   per-leaf {key, shape, dtype}, num_hosts
        COMMIT                  # written LAST, after every host finished —
                                #   a step dir without COMMIT is incomplete
    <dir>/LATEST                # advisory fast-path pointer (see latest_step)

Atomicity is the COMMIT barrier, not tmp-dir rename: multiple hosts write
into the same step dir concurrently, so no single rename can cover the save.
``manifest.json["format_version"]`` switches the reader; the legacy
single-file npz format (``arrays.npz`` + v1 manifest, no COMMIT) stays
readable — a v1 dir counts as complete when its ``arrays.npz`` exists.

Shard placement lives in the per-host index files (a host never knows the
byte offsets inside another host's file); the reader merges them.  Shard
``index`` entries are ``[[start, stop], ...]`` half-open ranges per dim of
the *global* array — the same coordinates ``jax.Array.addressable_shards``
exposes, so restore can intersect any on-disk layout with any target layout.
"""

from __future__ import annotations

import glob
import hashlib
import json
import os
import re
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = [
    "FORMAT_VERSION",
    "MANIFEST",
    "COMMIT",
    "LATEST",
    "shard_file",
    "index_file",
    "step_dir",
    "parse_step",
    "list_steps",
    "latest_step",
    "is_complete",
    "repair_interrupted_resaves",
    "read_manifest",
    "read_shard_index",
    "merged_shard_index",
    "write_latest",
    "sha_bytes",
    "dtype_from_str",
    "tree_structure_repr",
    "normalize_index",
]

FORMAT_VERSION = 2
MANIFEST = "manifest.json"
COMMIT = "COMMIT"
LATEST = "LATEST"
_STEP_RE = re.compile(r"^step_(\d{8})$")

# Serializes the writer's final stage->step_X swap against
# repair_interrupted_resaves (which may run from any thread via
# latest_step): without it, repair could rename a .replaced backup into
# place in the instant the writer is between its two renames, making the
# writer's own rename fail on a non-empty target.  In-process only;
# cross-process coordination stays with the COMMIT protocol.
swap_lock = threading.Lock()


def shard_file(process: int) -> str:
    return f"host_{process:05d}.bin"


def index_file(process: int) -> str:
    return f"index_host_{process:05d}.json"


def step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{step:08d}")


def parse_step(name: str) -> Optional[int]:
    m = _STEP_RE.match(name)
    return int(m.group(1)) if m else None


def sha_bytes(buf) -> str:
    return hashlib.sha256(buf).hexdigest()[:16]


def dtype_from_str(s: str) -> np.dtype:
    """np.dtype from a manifest dtype string, including the ml_dtypes
    extension types jax uses (bfloat16, float8_*)."""
    try:
        return np.dtype(s)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, s))


def tree_structure_repr(tree) -> str:
    """Canonical structure string for manifest validation.

    The treedef repr covers node types, arity, dict keys, and static aux data
    — for optimizer states that includes the transform-chain nesting and each
    ``QuantizedTensor``'s ``QuantConfig``."""
    return str(jax.tree_util.tree_structure(tree))


def normalize_index(index, shape) -> List[Tuple[int, int]]:
    """jax shard index (tuple of slices, possibly open) -> concrete
    half-open [start, stop) ranges per dim of the global shape."""
    out = []
    for sl, dim in zip(index, shape):
        start = 0 if sl.start is None else int(sl.start)
        stop = int(dim) if sl.stop is None else int(sl.stop)
        out.append((start, stop))
    return out


# ---------------------------------------------------------------------------
# manifest / index readers
# ---------------------------------------------------------------------------


def read_manifest(d: str) -> Dict[str, Any]:
    with open(os.path.join(d, MANIFEST)) as f:
        return json.load(f)


def read_shard_index(d: str, process: int) -> Dict[str, Any]:
    with open(os.path.join(d, index_file(process))) as f:
        return json.load(f)


def merged_shard_index(d: str) -> Dict[str, List[Dict[str, Any]]]:
    """leaf key -> shard records from every host's index file.

    Each record carries ``file`` (the host's bin file), ``offset``,
    ``nbytes``, ``index`` ranges, and ``sha256``."""
    merged: Dict[str, List[Dict[str, Any]]] = {}
    for p in sorted(glob.glob(os.path.join(glob.escape(d), "index_host_*.json"))):
        with open(p) as f:
            idx = json.load(f)
        fname = shard_file(idx["process"])
        for key, shards in idx["shards"].items():
            for s in shards:
                rec = dict(s)
                rec["file"] = fname
                merged.setdefault(key, []).append(rec)
    return merged


# ---------------------------------------------------------------------------
# completeness / step discovery
# ---------------------------------------------------------------------------


def is_complete(d: str) -> bool:
    """A step dir is restorable: v2 needs the COMMIT marker plus one index
    file per host; a legacy v1 dir needs its arrays.npz."""
    mpath = os.path.join(d, MANIFEST)
    if not os.path.exists(mpath):
        return False
    try:
        manifest = read_manifest(d)
    except (OSError, ValueError):
        return False
    if manifest.get("format_version", 1) < 2:
        return os.path.exists(os.path.join(d, "arrays.npz"))
    if not os.path.exists(os.path.join(d, COMMIT)):
        return False
    n_idx = len(glob.glob(os.path.join(glob.escape(d), "index_host_*.json")))
    return n_idx == int(manifest.get("num_hosts", 1))


def list_steps(directory: str, complete_only: bool = True) -> List[int]:
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        s = parse_step(name)
        if s is None:
            continue
        if complete_only and not is_complete(os.path.join(directory, name)):
            continue
        steps.append(s)
    return sorted(steps)


def repair_interrupted_resaves(directory: str) -> None:
    """Put durable copies back after a crashed re-save.

    Re-saving an already-committed step renames it to ``step_X.replaced``
    until the replacement commits; a kill in between leaves a complete
    backup next to an incomplete ``step_X``.  Restore the backup so the
    step stays reachable (and drop stale backups whose replacement did
    land).  Process 0 repairs; other hosts wait until nothing repairable
    remains, so every host's subsequent step scan sees the same set of
    complete dirs (no host can resume from a pre-repair view)."""
    if not os.path.isdir(directory):
        return

    def _repairable():
        out = []
        for name in os.listdir(directory):
            if not name.endswith(".replaced"):
                continue
            base = name[: -len(".replaced")]
            if parse_step(base) is None:
                continue
            out.append((os.path.join(directory, name), os.path.join(directory, base)))
        return out

    if jax.process_index() != 0:
        deadline = time.monotonic() + 600.0
        while any(is_complete(b) for b, _ in _repairable()):
            if time.monotonic() > deadline:
                raise TimeoutError(
                    "waiting for process 0 to repair interrupted re-saves in "
                    f"{directory}"
                )
            time.sleep(0.05)
        return
    with swap_lock:
        for name in os.listdir(directory):
            if not name.endswith(".replaced"):
                continue
            base = name[: -len(".replaced")]
            if parse_step(base) is None:
                continue
            bdir = os.path.join(directory, name)
            ddir = os.path.join(directory, base)
            if not is_complete(bdir):
                continue  # backup itself unusable; leave for inspection
            if is_complete(ddir):
                shutil.rmtree(bdir, ignore_errors=True)  # replacement landed
            else:
                if os.path.exists(ddir):
                    shutil.rmtree(ddir)
                os.rename(bdir, ddir)


def latest_step(directory: str) -> Optional[int]:
    """Newest *complete* step.  The LATEST pointer is only a fast path: if it
    names a step whose dir fails the completeness check (e.g. a save was
    killed mid-shard-write), fall back to scanning for the newest complete
    dir — this is the crash-recovery contract run_with_recovery relies on.
    Crashed re-saves are repaired first (their set-aside durable copy is
    renamed back into place)."""
    repair_interrupted_resaves(directory)
    p = os.path.join(directory, LATEST)
    if os.path.exists(p):
        try:
            with open(p) as f:
                s = int(f.read().strip())
            if is_complete(step_dir(directory, s)):
                return s
        except (OSError, ValueError):
            pass  # unreadable/garbled pointer: fall back to the dir scan
    steps = list_steps(directory, complete_only=True)
    return steps[-1] if steps else None


def write_latest(directory: str, step: int) -> None:
    tmp = os.path.join(directory, ".LATEST.tmp")
    with open(tmp, "w") as f:
        f.write(str(step))
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, os.path.join(directory, LATEST))
