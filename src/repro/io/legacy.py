"""Legacy (format v1) single-file npz checkpoint backend.

The seed format: every leaf gathered to one host and written into a single
``arrays.npz`` next to a v1 manifest (no ``format_version`` key, no COMMIT
marker — the tmp-dir rename was the atomicity unit).  Kept as a readable —
and, for migration tooling, writable — backend behind the manifest's
format-version switch; new saves go through ``repro.io.writer`` (sharded v2).
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.io.format import sha_bytes, tree_structure_repr, write_latest

__all__ = ["save_checkpoint_npz", "restore_npz"]


def _sha(a: np.ndarray) -> str:
    # the one checkpoint hash (v1 and v2 share it): format.sha_bytes
    return sha_bytes(np.ascontiguousarray(a).tobytes())


def _flatten_with_paths(tree) -> List[Tuple[str, np.ndarray]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out.append((key, np.asarray(leaf)))
    return out


def save_checkpoint_npz(
    directory: str, step: int, tree: Any, extra: Optional[Dict] = None
) -> str:
    """v1 atomic save: gather every leaf to this host, write one npz into a
    tmp dir, fsync, rename, update LATEST.  Single-host only by construction
    — this is exactly the gather-to-host-0 path the sharded format replaces."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        leaves = _flatten_with_paths(tree)
        arrays = {f"a{i}": arr for i, (_, arr) in enumerate(leaves)}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "extra": extra or {},
            "structure": tree_structure_repr(tree),
            "leaves": [
                {
                    "key": key,
                    "name": f"a{i}",
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "sha256": _sha(arr),
                }
                for i, (key, arr) in enumerate(leaves)
            ],
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    write_latest(directory, step)
    return final


def restore_npz(
    d: str,
    manifest: Dict,
    paths: List[str],
    sh_leaves: Optional[List[jax.sharding.Sharding]],
    validate: bool,
) -> List[jax.Array]:
    """Leaf arrays (in ``paths`` order) from a v1 dir.

    Every leaf is placed with ``jax.device_put`` straight onto its target
    sharding (default-device sharding when none was given) — the old path
    built ``jnp.asarray(arr)`` on the default device first and re-sharded
    from there, materializing each leaf twice."""
    npz = np.load(os.path.join(d, "arrays.npz"))
    by_key = {m["key"]: m for m in manifest["leaves"]}
    default = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    out = []
    for i, key in enumerate(paths):
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key}")
        m = by_key[key]
        arr = npz[m["name"]]
        if validate and _sha(arr) != m["sha256"]:
            raise IOError(f"checkpoint corruption at {key} (hash mismatch)")
        out.append(
            jax.device_put(arr, sh_leaves[i] if sh_leaves is not None else default)
        )
    return out
