"""Roofline measurement by decomposed compilation.

XLA's ``cost_analysis`` counts while-loop bodies ONCE (verified in
tests/test_roofline.py), so a full-module count under-reports every scanned
model by the trip counts. This module derives honest per-device roofline
terms from compiled artifacts anyway, by compiling each *scan-unit body*
separately — with inner scans (attention block-pairs, GLA chunks, CE chunks)
unrolled so the compiled module contains every op — and multiplying by the
known trip counts:

    total = Σ_unit  cost(unit body) × repeat
          + cost(embed / head+loss tails)
          + cost(optimizer update)                    (train only)

Remat is accounted explicitly: with remat on, the executed schedule is
forward + (forward recompute + backward), so a train unit contributes
cost(grad probe) + cost(fwd probe).

Sequence-linear units (SSM/GLA/sliding-window) are probed at
S_probe = min(S, 4096) and scaled by S/S_probe (their compute and activation
traffic are linear in S; weight traffic is slightly over-scaled — noted in
EXPERIMENTS.md). Quadratic units are probed at full length. The strictly
sequential sLSTM cell cannot be unrolled at 4k; its (small) recurrent matmul
cost is added analytically.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.core.optimizers import make_optimizer
from repro.launch.specs import decode_cache_len
from repro.models import ModelConfig, init_model, plan_scan_units
from repro.models.blocks import apply_block, init_block, init_block_cache
from repro.models.layers import chunked_cross_entropy, embed_lookup
from repro.models.model import _final_norm, _head_weight, ScanUnit
from repro.roofline.analysis import (
    HW,
    V5E,
    collective_bytes_from_hlo,
    cost_analysis_dict,
    model_flops,
    roofline_terms,
)
from repro.sharding.rules import dp_axes, dp_size, spec_for, with_zero
from repro.sharding.specs import opt_state_shardings, param_shardings, replicated

SDS = jax.ShapeDtypeStruct

_IS_AXES_LEAF = lambda a: isinstance(a, tuple) and all(isinstance(s, str) for s in a)

LINEAR_KINDS = ("mlstm", "slstm")


def _unit_is_linear(unit: ScanUnit) -> bool:
    """Compute/memory linear in S? (bounded window or recurrent state)"""
    for spec in unit.pattern:
        if spec.kind in LINEAR_KINDS:
            continue
        if spec.kind in ("dense", "moe", "hymba") and spec.window > 0:
            continue
        return False
    return True


def _probe_cfg(cfg: ModelConfig, S: int) -> ModelConfig:
    return dataclasses.replace(
        cfg,
        unroll_scans=True,
        remat=False,
        attn_q_chunk=2048 if S >= 16384 else 512,
        attn_k_chunk=2048 if S >= 16384 else 1024,
        decode_k_chunk=8192 if S >= 131072 else 2048,
        ce_chunk=2048 if S >= 16384 else 512,
        gla_chunk=1024 if S >= 16384 else cfg.gla_chunk,
    )


def _dp_sharding(mesh: Mesh, shape: Tuple[int, ...], batch_dim: int = 0):
    n_dp = dp_size(mesh)
    if n_dp > 1 and shape[batch_dim] % n_dp == 0:
        dps = dp_axes(mesh)
        entry = dps if len(dps) > 1 else dps[0]
        e = [None] * len(shape)
        e[batch_dim] = entry
        return NamedSharding(mesh, P(*e))
    return replicated(mesh)


def _layer_param_shardings(params_single, axes_single, mesh: Mesh):
    def one(x, a):
        a = a[1:] if a and a[0] == "layers" else a
        spec = spec_for(tuple(x.shape), a, mesh)
        spec = with_zero(tuple(x.shape), spec, mesh, axes=a)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map(
        one, params_single, axes_single, is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, dict)
    )


def _compile_cost(fn, args, in_shardings, mesh: Mesh, out_shardings=None):
    with mesh:
        lowered = jax.jit(
            fn, in_shardings=in_shardings, out_shardings=out_shardings
        ).lower(*args)
        compiled = lowered.compile()
    cost = cost_analysis_dict(compiled)
    hlo = compiled.as_text()
    return (
        float(cost.get("flops", 0.0)),
        float(cost.get("bytes accessed", 0.0)),
        hlo,
    )


def _slstm_correction(cfg: ModelConfig, B: int, S: int, backward: bool, n_layers: int):
    """Analytic flops for the sequential sLSTM recurrence (R h matmul):
    per step 2·4·D·dh MACs -> 4 gates × D × dh × 2 flops; ×3 with backward."""
    dh = cfg.d_model // cfg.num_heads
    per_step = 2.0 * 4 * cfg.d_model * dh
    mult = 3.0 if backward else 1.0
    return per_step * B * S * mult * n_layers  # global; caller divides by dp


@dataclasses.dataclass
class CellMeasurement:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    pieces: List[Dict[str, Any]] = dataclasses.field(default_factory=list)

    def add(self, name, flops, bytes_accessed, hlo, multiplier=1.0):
        coll = collective_bytes_from_hlo(hlo, multiplier=multiplier)
        self.flops += flops * multiplier
        self.bytes_accessed += bytes_accessed * multiplier
        self.collective_bytes += coll["total"]
        self.pieces.append(
            {
                "name": name,
                "multiplier": multiplier,
                "flops": flops,
                "bytes": bytes_accessed,
                "collective_bytes": coll["total"],
                "collective_ops": coll.get("ops", 0),
            }
        )

    def add_analytic(self, name, flops):
        self.flops += flops
        self.pieces.append({"name": name, "multiplier": 1, "flops": flops,
                            "bytes": 0.0, "collective_bytes": 0.0, "analytic": True})


def measure_cell(
    arch: str,
    shape_name: str,
    mesh: Mesh,
    hw: HW = V5E,
    optimizer: str = "adamw4bit",
) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    B = shape.global_batch
    S = shape.seq_len
    kind = shape.kind
    n_chips = mesh.devices.size

    meas = CellMeasurement()

    # real axes (python metadata) + param shapes
    closure = {}

    def capture():
        p, a = init_model(jax.random.PRNGKey(0), cfg)
        closure["axes"] = a
        return p

    params_s = jax.eval_shape(capture)
    axes = closure["axes"]

    D = cfg.d_model
    bf = jnp.bfloat16

    sections = [("decoder", cfg.blocks, axes.get("decoder"))]
    enc_out_sds = None
    S_dec = S
    if cfg.family == "encdec":
        S_dec = S // 2
        sections = [
            ("encoder", cfg.encoder_blocks, axes.get("encoder")),
            ("decoder", cfg.blocks, axes.get("decoder")),
        ]
        enc_out_sds = SDS((B, S_dec, D), bf)

    backward = kind == "train"

    for sec_name, blocks, sec_axes in sections:
        units = plan_scan_units(blocks)
        sec_S = S_dec if cfg.family == "encdec" else S
        for ui, unit in enumerate(units):
            linear = _unit_is_linear(unit) and kind != "decode"
            S_probe = min(sec_S, 4096) if linear else sec_S
            scale = sec_S / S_probe
            pcfg = _probe_cfg(cfg, S_probe)

            # single-layer params + axes
            p_single = {}
            a_single = {}
            for si, spec2 in enumerate(unit.pattern):
                ps = jax.eval_shape(
                    lambda sp=spec2: init_block(jax.random.PRNGKey(0), cfg, sp.kind)[0]
                )
                _, asx = init_block(jax.random.PRNGKey(0), cfg, spec2.kind)
                p_single[f"sub{si}"] = ps
                a_single[f"sub{si}"] = asx
            p_sh = {
                k: _layer_param_shardings(p_single[k], a_single[k], mesh)
                for k in p_single
            }

            if cfg.rope_variant == "mrope":
                positions = jnp.stack(
                    [jnp.broadcast_to(jnp.arange(S_probe)[None], (1, S_probe))] * 3
                )  # broadcast over batch at trace time is fine
                positions = None  # simplify: per-arch probes use default ids
            positions = None
            if kind != "decode" and cfg.rope_variant != "none" and unit.pattern[0].kind not in ("mlstm", "slstm", "enc"):
                positions = "arange"

            if kind == "decode":
                # one-token decode probe with single-layer cache
                s_max = decode_cache_len(cfg, shape)
                pdcfg = _probe_cfg(cfg, s_max)

                def mk_probe(unit=unit, pdcfg=pdcfg, s_max=s_max):
                    def probe(p_l, x, caches, pos):
                        h = x
                        new_c = {}
                        for si, sp in enumerate(unit.pattern):
                            pos_arg = pos[:, None] if pdcfg.rope_variant not in ("none",) else None
                            if pdcfg.rope_variant == "mrope":
                                pos_arg = jnp.stack([pos[:, None]] * 3)
                            h, nc, _ = apply_block(
                                p_l[f"sub{si}"], h, sp, pdcfg,
                                positions=pos_arg, cache=caches[f"sub{si}"],
                                cur_pos=pos, enc_out=None,
                            )
                            new_c[f"sub{si}"] = nc
                        return h, new_c
                    return probe

                caches_s = {
                    f"sub{si}": jax.eval_shape(
                        lambda sp=sp2: init_block_cache(cfg, sp, B, s_max)
                    )
                    for si, sp2 in enumerate(unit.pattern)
                }
                cache_sh = jax.tree_util.tree_map(
                    lambda leaf: _dp_sharding(mesh, leaf.shape, 0)
                    if leaf.shape and leaf.shape[0] % dp_size(mesh) == 0
                    else (
                        _dp_sharding(mesh, leaf.shape, 1)
                        if len(leaf.shape) > 1 and "data" in mesh.axis_names
                        and leaf.shape[1] % dict(zip(mesh.axis_names, mesh.devices.shape))["data"] == 0
                        and leaf.shape[1] >= 256
                        else replicated(mesh)
                    ),
                    caches_s,
                )
                x_s = SDS((B, 1, D), bf)
                pos_s = SDS((B,), jnp.int32)
                fl, by, hlo = _compile_cost(
                    mk_probe(),
                    (p_single, x_s, caches_s, pos_s),
                    (p_sh, _dp_sharding(mesh, (B, 1, D)), cache_sh, _dp_sharding(mesh, (B,))),
                    mesh,
                    out_shardings=(_dp_sharding(mesh, (B, 1, D)), cache_sh),
                )
                meas.add(f"{sec_name}/unit{ui}/decode", fl, by, hlo, unit.repeat)
                continue

            # train / prefill probes
            enc_arg = enc_out_sds if unit.pattern[0].kind == "dec" else None

            def mk_fwd(unit=unit, pcfg=pcfg, positions=positions, S_probe=S_probe, enc_arg=enc_arg):
                def fwd(p_l, x, enc=None):
                    h = x
                    pos = None
                    if positions == "arange":
                        pos = jnp.broadcast_to(jnp.arange(S_probe)[None], (x.shape[0], S_probe))
                        if pcfg.rope_variant == "mrope":
                            pos = jnp.stack([pos] * 3)
                    aux = jnp.float32(0)
                    for si, sp in enumerate(unit.pattern):
                        h, _, a = apply_block(
                            p_l[f"sub{si}"], h, sp, pcfg,
                            positions=pos, cache=None, cur_pos=None, enc_out=enc,
                        )
                        aux = aux + a
                    return h, aux
                return fwd

            x_s = SDS((B, S_probe, D), bf)
            x_sh = _dp_sharding(mesh, (B, S_probe, D))
            fwd = mk_fwd()

            if backward:
                def probe_grad(p_l, x, cot, enc=None):
                    def scalar(p_l, x):
                        h, aux = fwd(p_l, x, enc)
                        return jnp.sum(h.astype(jnp.float32) * cot) + aux
                    g = jax.grad(scalar, argnums=(0, 1))(p_l, x)
                    return g

                cot_s = SDS((B, S_probe, D), jnp.float32)
                args = (p_single, x_s, cot_s) + ((enc_arg,) if enc_arg is not None else ())
                shard = (p_sh, x_sh, x_sh) + ((_dp_sharding(mesh, enc_arg.shape),) if enc_arg is not None else ())
                fl, by, hlo = _compile_cost(probe_grad, args, shard, mesh,
                                            out_shardings=(p_sh, x_sh))
                meas.add(f"{sec_name}/unit{ui}/grad", fl, by, hlo, unit.repeat * scale)
                if cfg.remat:
                    fl2, by2, hlo2 = _compile_cost(
                        lambda p_l, x, enc=None: fwd(p_l, x, enc)[0],
                        (p_single, x_s) + ((enc_arg,) if enc_arg is not None else ()),
                        (p_sh, x_sh) + ((_dp_sharding(mesh, enc_arg.shape),) if enc_arg is not None else ()),
                        mesh,
                        out_shardings=x_sh,
                    )
                    meas.add(f"{sec_name}/unit{ui}/remat_fwd", fl2, by2, hlo2, unit.repeat * scale)
            else:
                args = (p_single, x_s) + ((enc_arg,) if enc_arg is not None else ())
                shard = (p_sh, x_sh) + ((_dp_sharding(mesh, enc_arg.shape),) if enc_arg is not None else ())
                fl, by, hlo = _compile_cost(
                    lambda p_l, x, enc=None: fwd(p_l, x, enc)[0], args, shard, mesh,
                    out_shardings=x_sh,
                )
                meas.add(f"{sec_name}/unit{ui}/fwd", fl, by, hlo, unit.repeat * scale)

            n_slstm = sum(1 for sp in unit.pattern if sp.kind == "slstm")
            if n_slstm:
                # per-device share: the recurrence is batch-parallel over dp
                meas.add_analytic(
                    f"{sec_name}/unit{ui}/slstm_recurrence",
                    _slstm_correction(cfg, B, S_probe, backward, n_slstm)
                    * unit.repeat * scale / max(1, dp_size(mesh)),
                )

    # ---- tails -----------------------------------------------------------
    pcfg_tail = _probe_cfg(cfg, S_dec)
    head_shape = (
        params_s["embed"].shape if cfg.tie_embeddings else params_s["head"].shape
    )
    fn_s = params_s["final_norm"]
    x_s = SDS((B, S_dec, D), bf)
    x_sh = _dp_sharding(mesh, (B, S_dec, D))
    head_sds = SDS(head_shape, jnp.float32)
    head_axes = ("vocab", "embed") if cfg.tie_embeddings else ("embed", "vocab")
    head_sh = NamedSharding(
        mesh, with_zero(head_shape, spec_for(head_shape, head_axes, mesh), mesh)
    )

    if kind == "train":
        labels_s = SDS((B, S_dec), jnp.int32)

        def tail(head_w, norm_p, x, labels):
            xf = _final_norm(cfg, x, norm_p)
            hw_mat = head_w.T if cfg.tie_embeddings else head_w
            return chunked_cross_entropy(
                xf, hw_mat, labels, logit_cap=cfg.final_softcap,
                chunk=pcfg_tail.ce_chunk, unroll=True,
            )

        def tail_grad(head_w, norm_p, x, labels):
            return jax.grad(tail, argnums=(0, 1, 2))(head_w, norm_p, x, labels)

        fl, by, hlo = _compile_cost(
            tail_grad,
            (head_sds, fn_s, x_s, labels_s),
            (head_sh, None, x_sh, _dp_sharding(mesh, (B, S_dec))),
            mesh,
            out_shardings=(head_sh, None, x_sh),
        )
        meas.add("tail/loss_grad", fl, by, hlo)

        if cfg.input_mode == "tokens":
            emb_sds = SDS(params_s["embed"].shape, jnp.float32)
            emb_sh = NamedSharding(
                mesh,
                with_zero(
                    params_s["embed"].shape,
                    spec_for(params_s["embed"].shape, ("vocab", "embed"), mesh),
                    mesh,
                ),
            )
            tok_s = SDS((B, S_dec), jnp.int32)

            def emb_probe(emb, toks, cot):
                return jnp.sum(embed_lookup(emb, toks).astype(jnp.float32) * cot)

            fl, by, hlo = _compile_cost(
                lambda e, t, c: jax.grad(emb_probe)(e, t, c),
                (emb_sds, tok_s, SDS((B, S_dec, D), jnp.float32)),
                (emb_sh, _dp_sharding(mesh, (B, S_dec)), x_sh),
                mesh,
                out_shardings=emb_sh,
            )
            meas.add("tail/embed_grad", fl, by, hlo)

        # optimizer update over the full parameter set (elementwise, no scans)
        opt = make_optimizer(optimizer, 1e-4)
        params_zeros = lambda: jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), params_s
        )
        state_s = jax.eval_shape(lambda: opt.init(params_zeros()))
        grads_s = jax.tree_util.tree_map(lambda s: SDS(s.shape, jnp.float32), params_s)
        p_shard = param_shardings(params_s, axes, mesh, zero=True)
        s_shard = opt_state_shardings(state_s, params_s, axes, mesh, zero=True)
        g_shard = jax.tree_util.tree_map(
            lambda sh: sh, p_shard
        )  # grads in ZeRO layout too

        def opt_probe(grads, state, params):
            new_p, new_s = opt.update(grads, state, params)
            return new_p, new_s

        fl, by, hlo = _compile_cost(
            opt_probe, (grads_s, state_s, params_s), (g_shard, s_shard, p_shard),
            mesh, out_shardings=(p_shard, s_shard),
        )
        meas.add("tail/optimizer_update", fl, by, hlo)
    else:
        # prefill/decode logits tail: one position (decode) or last (prefill)
        def logits_tail(head_w, norm_p, x):
            xf = _final_norm(cfg, x[:, -1:], norm_p)
            hw_mat = head_w.T if cfg.tie_embeddings else head_w
            return jnp.einsum("bsd,dv->bsv", xf.astype(bf), hw_mat.astype(bf))

        n_pos = 1 if kind == "decode" else S_dec
        fl, by, hlo = _compile_cost(
            logits_tail,
            (SDS(head_shape, bf), fn_s, SDS((B, n_pos, D), bf)),
            (head_sh, None, _dp_sharding(mesh, (B, n_pos, D))),
            mesh,
            out_shardings=_dp_sharding(mesh, (B, n_pos, 8)),
        )
        meas.add("tail/logits", fl, by, hlo)

    tokens = B * (S if kind != "decode" else 1)
    mflops = model_flops(cfg, params_s, axes, kind, tokens)
    terms = roofline_terms(
        {"flops": meas.flops, "bytes accessed": meas.bytes_accessed},
        meas.collective_bytes,
        n_chips,
        mflops,
        hw,
    )
    return {
        "arch": arch,
        "shape": shape_name,
        "n_chips": n_chips,
        "method": "decomposed-compile (per-unit bodies x trip counts)",
        "roofline": terms.as_dict(),
        "pieces": meas.pieces,
    }
