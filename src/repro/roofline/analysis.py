"""Roofline terms from compiled dry-run artifacts (TPU v5e targets).

    compute term    = HLO_FLOPs / peak_FLOP/s          (per chip)
    memory term     = HLO_bytes / HBM_bw               (per chip)
    collective term = collective_bytes / link_bw       (per chip)

``cost_analysis`` of the SPMD-partitioned module reports per-device FLOPs and
bytes. Collective bytes are not in cost_analysis — we parse the optimized
HLO text and sum operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (per-device shapes, so the
term is already per-chip).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional

__all__ = [
    "HW",
    "RooflineTerms",
    "collective_bytes_from_hlo",
    "cost_analysis_dict",
    "roofline_terms",
    "model_flops",
]


def cost_analysis_dict(compiled) -> Dict[str, Any]:
    """``compiled.cost_analysis()`` across jax versions.

    Older jax returns a dict; newer jax returns a one-element list of dicts
    (one per partition/program). Normalizes to the single dict every caller
    wants (empty dict if the analysis is unavailable).
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


@dataclasses.dataclass(frozen=True)
class HW:
    """TPU v5e-class hardware constants (per chip)."""

    peak_flops: float = 197e12    # bf16 FLOP/s
    hbm_bw: float = 819e9         # B/s
    link_bw: float = 50e9         # B/s per ICI link


V5E = HW()

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# result shape after '=', e.g.  %ag = bf16[16,512]{1,0} all-gather(%x), ...
_RESULT_RE = re.compile(r"=\s+(?:\()?\s*(pred|[usfb]\w{1,4})\[([0-9,]*)\]")
_GROUPS_ARRAY_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(dtype: str, dims: str) -> float:
    if dtype not in _DTYPE_BYTES:
        return 0.0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str) -> int:
    m = _GROUPS_ARRAY_RE.search(line)
    if m:
        return max(1, int(m.group(2)))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(1, len(m.group(1).split(",")))
    return 1


def _ring_bytes(kind: str, result_bytes: float, k: int) -> float:
    """Per-device link traffic under ring algorithms (documented choice):
    all-reduce 2(K-1)/K·R; all-gather (K-1)/K·R (R = gathered result);
    reduce-scatter (K-1)·R (operand is K×result); all-to-all (K-1)/K·R;
    collective-permute R."""
    if kind == "collective-permute":
        return result_bytes  # no group semantics; one hop of R bytes
    if k <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (k - 1) / k * result_bytes
    if kind == "all-gather":
        return (k - 1) / k * result_bytes
    if kind == "reduce-scatter":
        return float(k - 1) * result_bytes
    return (k - 1) / k * result_bytes  # all-to-all


def collective_bytes_from_hlo(hlo_text: str, multiplier: float = 1.0) -> Dict[str, float]:
    """Per-collective-kind link bytes (per device) from optimized HLO.

    Parses result shapes + replica_groups per collective line and applies
    ring-traffic formulas. ``multiplier`` scales everything (used when a
    parsed module is one scan-body iteration executed N times).
    """
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for kind in _COLLECTIVES:
            if f" {kind}(" not in stripped and f" {kind}-start(" not in stripped:
                continue
            m = _RESULT_RE.search(stripped)
            if not m:
                break
            rbytes = _shape_bytes(m.group(1), m.group(2))
            k = _group_size(stripped)
            out[kind] += _ring_bytes(kind, rbytes, k) * multiplier
            counts[kind] += 1
            break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["ops"] = float(sum(counts.values()))
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float               # per-device HLO FLOPs
    bytes_accessed: float      # per-device HLO bytes
    collective_bytes: float    # per-device collective operand bytes
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops_total: float   # 6·N·D (global, useful work)
    useful_ratio: float        # model_flops / (flops × chips)

    def as_dict(self):
        return dataclasses.asdict(self)


def roofline_terms(
    cost: Dict[str, Any],
    collective_bytes: float,
    n_chips: int,
    model_flops_total: float,
    hw: HW = V5E,
) -> RooflineTerms:
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    compute_s = flops / hw.peak_flops
    memory_s = bytes_accessed / hw.hbm_bw
    collective_s = collective_bytes / hw.link_bw
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    useful = model_flops_total / (flops * n_chips) if flops > 0 else 0.0
    return RooflineTerms(
        flops=flops,
        bytes_accessed=bytes_accessed,
        collective_bytes=collective_bytes,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops_total=model_flops_total,
        useful_ratio=useful,
    )


def count_params(params_shapes, axes) -> Dict[str, float]:
    """(total, active) parameter counts. Expert weights count active as
    top_k/num_experts of their size — set by the caller via axes marking."""
    import jax

    is_axes_leaf = lambda a: isinstance(a, tuple) and all(isinstance(s, str) for s in a)
    p_leaves = jax.tree_util.tree_leaves(params_shapes)
    a_leaves = jax.tree_util.tree_leaves(axes, is_leaf=is_axes_leaf)
    total = 0
    expert = 0
    for p, a in zip(p_leaves, a_leaves):
        n = 1
        for d in p.shape:
            n *= d
        total += n
        if "experts" in a:
            expert += n
    return {"total": float(total), "expert": float(expert)}


def model_flops(
    cfg,
    params_shapes,
    axes,
    shape_kind: str,
    tokens: int,
) -> float:
    """Useful-work FLOPs: 6·N_active·D for training, 2·N_active·D for
    inference (prefill per token; decode per generated token)."""
    counts = count_params(params_shapes, axes)
    n_active = counts["total"] - counts["expert"]
    if cfg.num_experts > 0 and counts["expert"] > 0:
        n_active += counts["expert"] * cfg.top_k / cfg.num_experts
    factor = 6.0 if shape_kind == "train" else 2.0
    return factor * n_active * tokens
