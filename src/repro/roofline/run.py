import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# device count must be locked before any jax import (same rule as dryrun.py)
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_DRYRUN_DEVICES"]
    )

"""Roofline measurement runner: single-pod mesh, every runnable cell.

    python -m repro.roofline.run --arch xlstm-125m --shape train_4k
    python -m repro.roofline.run --all --out results/roofline.json
"""

import argparse
import json
import traceback

from repro.configs import ARCHS, SHAPES, cell_is_runnable
from repro.launch.mesh import make_production_mesh
from repro.roofline.measured import measure_cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/roofline.json")
    ap.add_argument("--order", default=None, help="comma-separated arch order")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=False)

    if not args.all:
        rec = measure_cell(args.arch, args.shape, mesh)
        print(json.dumps(rec, indent=1, default=str))
        return

    results = []
    if os.path.exists(args.out):
        results = json.load(open(args.out))
    done = {(r["arch"], r["shape"]) for r in results}
    archs = args.order.split(",") if args.order else list(ARCHS)
    for arch in archs:
        for shape_name in SHAPES:
            if (arch, shape_name) in done:
                continue
            runnable, reason = cell_is_runnable(arch, shape_name)
            if not runnable:
                results.append({"arch": arch, "shape": shape_name,
                                "status": "skipped", "reason": reason})
                continue
            print(f"=== roofline {arch} x {shape_name} ===", flush=True)
            try:
                rec = measure_cell(arch, shape_name, mesh)
                rec["status"] = "ok"
            except Exception as e:
                rec = {"arch": arch, "shape": shape_name, "status": "error",
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-1500:]}
                print(rec["error"], flush=True)
            results.append(rec)
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            json.dump(results, open(args.out, "w"), indent=1)
    print("ROOFLINE SWEEP COMPLETE")


if __name__ == "__main__":
    main()
