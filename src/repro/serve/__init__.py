"""Serving: continuous-batching engine, on-device sampling, weight formats."""

from repro.serve.engine import Request, ServeEngine
from repro.serve.sampling import sample_tokens, request_key_words
from repro.serve.weights import (
    WEIGHT_MODES,
    WEIGHT_Q4,
    format_weight_table,
    materialize,
    prepare_params,
    weight_report,
)

__all__ = [
    "Request",
    "ServeEngine",
    "sample_tokens",
    "request_key_words",
    "WEIGHT_MODES",
    "WEIGHT_Q4",
    "prepare_params",
    "materialize",
    "weight_report",
    "format_weight_table",
]
