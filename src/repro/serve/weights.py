"""Serving weight formats: bf16 cast or 4-bit block-quantized, with exact
byte accounting.

The bitsandbytes line of work framed weight quantization for inference as a
"change one line" story; this module is that line for the serving engine.
``prepare_params`` rewrites the fp32 master tree into the serving format:

* ``bf16`` — matmul-scale leaves cast to bf16 (the compute dtype anyway);
  small leaves (norm scales, biases, anything at or under ``threshold``
  elements or below rank 2) stay fp32, so serving numerics match the fp32
  masters bit-for-bit (the model casts to bf16 at each matmul regardless).
* ``q4``  — the same eligible leaves stored as ``QuantizedTensor`` under
  B128/DE (blockwise-128 normalization, 4-bit dynamic-exponent map with a
  real zero code — the Dettmers dynamic map, which suits weight
  distributions; the zero-excluding linear map is for second moments).

The HBM-resident copy stays compressed; ``materialize`` dequantizes inside
the jitted prefill/decode step (dequant-on-use), so the fp32 view is a
transient the compiler can fuse into the consuming matmul.

``weight_report`` mirrors ``repro.comms.accounting.wire_report``: structural
per-leaf rows (works on shapes alone), totals, and the q4-vs-bf16 ratio
that the serving drift gate tracks.
"""

from __future__ import annotations

from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from repro.core.optimizers.base import tree_paths
from repro.core.quantizer import (
    QuantConfig,
    QuantizedTensor,
    dequantize,
    quantize,
    quantized_nbytes,
)

__all__ = [
    "WEIGHT_Q4",
    "WEIGHT_MODES",
    "prepare_params",
    "materialize",
    "weight_report",
    "format_weight_table",
]

# B128/DE: blockwise-128 absmax scales + the signed dynamic-exponent map.
WEIGHT_Q4 = QuantConfig(
    bits=4, normalization="blockwise", block_size=128, mapping="de", signed=True
)
WEIGHT_MODES = ("bf16", "q4")

# Same small-tensor cutoff the optimizer states use (App. D.1): leaves this
# small are noise in the memory budget and precision-critical (norm scales).
DEFAULT_THRESHOLD = 4096


def _eligible(shape, threshold: int) -> bool:
    n = 1
    for d in shape:
        n *= int(d)
    return len(shape) >= 2 and n > threshold


def prepare_params(params, mode: str, *, threshold: int = DEFAULT_THRESHOLD):
    """fp32 master tree -> serving tree (``bf16`` casts or ``q4`` tensors)."""
    if mode not in WEIGHT_MODES:
        raise ValueError(f"unknown weights mode {mode!r}; want one of {WEIGHT_MODES}")

    def prep(leaf):
        if not _eligible(leaf.shape, threshold):
            return jnp.asarray(leaf, jnp.float32)
        if mode == "bf16":
            return jnp.asarray(leaf, jnp.bfloat16)
        return quantize(jnp.asarray(leaf, jnp.float32), WEIGHT_Q4)

    return jax.tree_util.tree_map(prep, params)


def materialize(serving_params):
    """Dequantize-on-use: expand ``QuantizedTensor`` leaves to fp32 views.

    Called *inside* the jitted step, so the expansion is a transient — the
    persistent HBM copy keeps the compressed layout.
    """
    return jax.tree_util.tree_map(
        lambda x: dequantize(x) if isinstance(x, QuantizedTensor) else x,
        serving_params,
        is_leaf=lambda x: isinstance(x, QuantizedTensor),
    )


def _leaf_bytes(shape, mode: str, threshold: int) -> int:
    n = 1
    for d in shape:
        n *= int(d)
    if not _eligible(shape, threshold):
        return n * 4
    if mode == "bf16":
        return n * 2
    return quantized_nbytes(shape, WEIGHT_Q4)


def weight_report(params, mode: str, *, threshold: int = DEFAULT_THRESHOLD) -> Dict:
    """Per-leaf and total weight bytes under a serving mode.

    ``params`` is any tree of array-likes with ``.shape`` (concrete arrays
    or ``ShapeDtypeStruct`` — structural, nothing is allocated). Totals are
    exact; ``ratio_vs_bf16`` is what the drift gate floors at 3.5x.
    """
    if mode not in WEIGHT_MODES:
        raise ValueError(f"unknown weights mode {mode!r}; want one of {WEIGHT_MODES}")
    leaves = jax.tree_util.tree_leaves(params)
    paths = jax.tree_util.tree_leaves(tree_paths(params))
    rows: List[Dict[str, Any]] = []
    total = total_bf16 = 0
    quantized_leaves = 0
    for path, leaf in zip(paths, leaves):
        shape = tuple(leaf.shape)
        nbytes = _leaf_bytes(shape, mode, threshold)
        bf16 = _leaf_bytes(shape, "bf16", threshold)
        quantized = mode == "q4" and _eligible(shape, threshold)
        quantized_leaves += int(quantized)
        rows.append(
            {
                "path": path,
                "shape": shape,
                "bf16_bytes": bf16,
                "serve_bytes": nbytes,
                "quantized": quantized,
            }
        )
        total += nbytes
        total_bf16 += bf16
    return {
        "mode": mode,
        "format": WEIGHT_Q4.name if mode == "q4" else "bf16",
        "leaves": rows,
        "n_leaves": len(rows),
        "quantized_leaves": quantized_leaves,
        "total_bf16_bytes": int(total_bf16),
        "total_serve_bytes": int(total),
        "ratio_vs_bf16": round(total_bf16 / total, 4) if total else 1.0,
    }


def format_weight_table(reports: List[Dict], title: str = "") -> str:
    """Markdown weight-memory table (CI step summary / docs)."""
    lines = []
    if title:
        lines += [f"### {title}", ""]
    lines += [
        "| --weights | format | weight bytes | vs bf16 | quantized leaves |",
        "|---|---|---|---|---|",
    ]
    for r in reports:
        lines.append(
            f"| {r['mode']} | {r['format']} | {r['total_serve_bytes']:,} "
            f"| {r['ratio_vs_bf16']:.2f}x fewer "
            f"| {r['quantized_leaves']}/{r['n_leaves']} |"
        )
    return "\n".join(lines)
