"""Batched serving engine: continuous-batching decode over the model zoo.

Requests enter a queue; the engine packs up to ``max_batch`` active streams
into the fixed-size cache slots, steps them together with one jitted
``decode_step``, retires finished streams (EOS or max_tokens), and backfills
free slots from the queue — the standard continuous-batching loop.
4-bit-relevant: serving weights are bf16 (no optimizer states at all), so the
paper's memory story here is about the training side; the engine exists to
run the decode shapes end-to-end at small scale.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig, decode_step, init_serve_cache

__all__ = ["Request", "ServeEngine"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        max_batch: int = 4,
        s_max: int = 256,
        greedy: bool = True,
    ):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.s_max = s_max
        self.greedy = greedy
        self.caches = init_serve_cache(cfg, max_batch, s_max)
        self.pos = np.zeros((max_batch,), np.int32)
        self.active: List[Optional[Request]] = [None] * max_batch
        self.pending_tokens: List[List[int]] = [[] for _ in range(max_batch)]
        self.queue: List[Request] = []
        self._step = jax.jit(
            lambda p, c, t, q: decode_step(p, cfg, c, t, q)
        )

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.max_batch):
            if self.active[slot] is None and self.queue:
                req = self.queue.pop(0)
                self.active[slot] = req
                # feed the prompt token-by-token (teacher-forced prefill)
                self.pending_tokens[slot] = list(req.prompt)
                self.pos[slot] = 0

    def step(self) -> bool:
        """One engine tick. Returns False when idle."""
        self._admit()
        if all(r is None for r in self.active):
            return False

        tokens = np.zeros((self.max_batch,), np.int32)
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            if self.pending_tokens[slot]:
                tokens[slot] = self.pending_tokens[slot].pop(0)
            elif req.output:
                tokens[slot] = req.output[-1]
            else:
                tokens[slot] = req.prompt[-1]

        logits, self.caches = self._step(
            self.params, self.caches, jnp.asarray(tokens), jnp.asarray(self.pos)
        )
        next_tok = np.asarray(jnp.argmax(logits, axis=-1))

        for slot, req in enumerate(self.active):
            if req is None:
                continue
            self.pos[slot] += 1
            if self.pending_tokens[slot]:
                continue  # still prefilling this stream
            req.output.append(int(next_tok[slot]))
            hit_eos = req.eos_id is not None and req.output[-1] == req.eos_id
            if hit_eos or len(req.output) >= req.max_new_tokens:
                req.done = True
                self.active[slot] = None  # retire; slot backfills next tick
        return True

    def run(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            if not self.step() and not self.queue:
                break
