"""Throughput-oriented continuous-batching serving engine.

The engine owns ``max_batch`` fixed cache slots and drives them through the
admit → prefill → decode → retire loop:

* **admit/prefill** — queued requests are packed into free slots and their
  prompts consumed in ONE forward pass (``prefill_with_cache``): the batch
  is right-padded to a power-of-two bucket (bounded recompiles), K/V and
  recurrent states land in a fresh cache, and the result is merged into the
  live cache only at admitted slots — so a slot's history is rebuilt from
  scratch on every backfill and stale state from the previous occupant
  cannot survive. The first token of each stream is sampled from the
  prefill logits on device.
* **decode** — a jitted ``lax.scan`` over ``drain_every`` decode steps.
  Sampling (temperature / top-k, Gumbel-max) happens on device with
  counter-based Threefry streams keyed by (engine seed, request id), so the
  host syncs ONCE per ``drain_every`` tokens (one small (N, B) transfer)
  instead of every tick — the host-sync-every-N contract.
* **retire** — at each drain the host walks the freshly generated tokens,
  finishes streams on EOS / ``max_new_tokens`` (tokens a dead slot decoded
  past its end inside the chunk are discarded), frees their slots, and
  backfills from the queue on the next tick.

Weights are served in the format picked by ``weights=``: ``bf16`` casts of
the fp32 masters, or ``q4`` — 4-bit block-quantized ``QuantizedTensor``
leaves (B128/DE via ``core/quantizer.py``) that stay compressed in HBM and
are dequantized on use inside the jitted steps (``serve.weights``).

Sampled streams are reproducible and slot-order-invariant: the noise
counter is (request id, generated-token index), never the slot id or tick
(``serve.sampling``).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import (
    ModelConfig,
    decode_step,
    init_serve_cache,
    prefill_with_cache,
)
from repro.serve.sampling import request_key_words, sample_tokens
from repro.serve.weights import materialize, prepare_params, weight_report

__all__ = ["Request", "ServeEngine"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    temperature: float = 0.0  # 0 = greedy
    top_k: int = 0            # 0 = full vocab
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def _bucket_len(n: int, lo: int = 16) -> int:
    """Next power of two >= n (>= lo): the static prefill width, so distinct
    prompt lengths share a handful of compiled prefill shapes."""
    b = lo
    while b < n:
        b *= 2
    return b


class ServeEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        max_batch: int = 4,
        s_max: int = 256,
        weights: str = "bf16",
        drain_every: int = 8,
        seed: int = 0,
    ):
        if cfg.family != "decoder" or cfg.input_mode != "tokens":
            raise ValueError("ServeEngine serves token-decoder archs only")
        self.cfg = cfg
        self.max_batch = max_batch
        self.s_max = s_max
        self.weights_mode = weights
        self.drain_every = drain_every
        self.seed = seed

        self.params = prepare_params(params, weights)
        self._master_struct = jax.eval_shape(lambda t: t, params)
        self.caches = init_serve_cache(cfg, max_batch, s_max)

        # Per-slot device-mirrored state (host copies are the authority;
        # device arrays are rebuilt from them at each dispatch).
        self.tokens = np.zeros((max_batch,), np.int32)   # last sampled token
        self.pos = np.zeros((max_batch,), np.int32)      # its absolute position
        self.kw = np.zeros((max_batch, 2), np.uint32)    # sampling key words
        self.gen_idx = np.zeros((max_batch,), np.int32)  # tokens sampled so far
        self.temp = np.zeros((max_batch,), np.float32)
        self.topk = np.zeros((max_batch,), np.int32)

        self.active: List[Optional[Request]] = [None] * max_batch
        self.queue: List[Request] = []

        B = max_batch

        def _prefill(params, caches, tokens, lengths, admit,
                     kw, temp, topk, cur_tok, cur_pos, cur_gen):
            p = materialize(params)
            fresh = init_serve_cache(cfg, B, s_max)
            logits, fresh = prefill_with_cache(p, cfg, tokens, lengths, fresh)
            first = sample_tokens(
                logits, kw, jnp.zeros((B,), jnp.int32), temp, topk
            )

            def merge(new, old):
                mask = admit.reshape((1, B) + (1,) * (new.ndim - 2))
                return jnp.where(mask, new, old)

            caches = jax.tree_util.tree_map(merge, fresh, caches)
            tok = jnp.where(admit, first, cur_tok)
            pos = jnp.where(admit, lengths, cur_pos)
            gen = jnp.where(admit, 1, cur_gen)
            return caches, tok, pos, gen, first

        def _decode(params, caches, tokens, pos, kw, gen, temp, topk):
            p = materialize(params)

            def body(carry, _):
                caches, tok, pos, gi = carry
                logits, caches = decode_step(p, cfg, caches, tok, pos)
                nxt = sample_tokens(logits, kw, gi, temp, topk)
                return (caches, nxt, pos + 1, gi + 1), nxt

            (caches, tok, pos, gen), toks = jax.lax.scan(
                body, (caches, tokens, pos, gen), None, length=drain_every
            )
            return caches, tok, pos, gen, toks  # toks: (N, B)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def weight_bytes(self) -> dict:
        """Exact weight-memory accounting for the serving format
        (structural — computed from the master tree's shapes)."""
        return weight_report(self._master_struct, self.weights_mode)

    # ------------------------------------------------------------------
    def _admit_and_prefill(self) -> List[int]:
        """Fill free slots from the queue; one batched prefill for all."""
        admitted: List[int] = []
        for slot in range(self.max_batch):
            if self.active[slot] is None and self.queue:
                req = self.queue.pop(0)
                self.active[slot] = req
                k0, k1 = request_key_words(self.seed, req.rid)
                self.kw[slot] = (int(k0), int(k1))
                self.temp[slot] = req.temperature
                self.topk[slot] = req.top_k
                admitted.append(slot)
        if not admitted:
            return admitted

        S = _bucket_len(max(len(self.active[s].prompt) for s in admitted))
        toks = np.zeros((self.max_batch, S), np.int32)
        lens = np.zeros((self.max_batch,), np.int32)
        admit = np.zeros((self.max_batch,), bool)
        for slot in admitted:
            p = self.active[slot].prompt
            toks[slot, : len(p)] = p
            lens[slot] = len(p)
            admit[slot] = True

        self.caches, tok, pos, gen, _ = self._prefill(
            self.params, self.caches,
            jnp.asarray(toks), jnp.asarray(lens), jnp.asarray(admit),
            jnp.asarray(self.kw), jnp.asarray(self.temp),
            jnp.asarray(self.topk), jnp.asarray(self.tokens),
            jnp.asarray(self.pos), jnp.asarray(self.gen_idx),
        )
        self.tokens = np.asarray(tok)
        self.pos = np.asarray(pos)
        self.gen_idx = np.asarray(gen)

        for slot in admitted:
            req = self.active[slot]
            req.output.append(int(self.tokens[slot]))
            self._maybe_retire(slot)
        return admitted

    def _maybe_retire(self, slot: int) -> None:
        req = self.active[slot]
        hit_eos = req.eos_id is not None and req.output and req.output[-1] == req.eos_id
        if hit_eos or len(req.output) >= req.max_new_tokens:
            req.done = True
            self.active[slot] = None  # slot backfills at the next tick

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """One engine tick: admit+prefill, then decode ``drain_every``
        tokens on device and drain them. Returns False when idle."""
        self._admit_and_prefill()
        if all(r is None for r in self.active):
            return False

        self.caches, tok, pos, gen, toks = self._decode(
            self.params, self.caches,
            jnp.asarray(self.tokens), jnp.asarray(self.pos),
            jnp.asarray(self.kw), jnp.asarray(self.gen_idx),
            jnp.asarray(self.temp), jnp.asarray(self.topk),
        )
        # ONE host sync per drain_every tokens: the (N, B) token block.
        toks = np.asarray(toks)
        self.tokens = np.asarray(tok)
        self.pos = np.asarray(pos)
        self.gen_idx = np.asarray(gen)

        for slot in range(self.max_batch):
            req = self.active[slot]
            if req is None:
                continue
            for n in range(toks.shape[0]):
                req.output.append(int(toks[n, slot]))
                self._maybe_retire(slot)
                if self.active[slot] is None:
                    break  # chunk tokens past the end are discarded
        return True

    def run(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            if not self.step() and not self.queue:
                break
