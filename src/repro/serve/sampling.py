"""On-device token sampling with counter-based Threefry streams.

The engine keeps the decode loop on device for N tokens at a time, so the
sampler must be (a) jittable, (b) per-slot parameterized (temperature /
top-k vary per request), and (c) reproducible regardless of *where* a
request happens to sit: slot assignment is a scheduling accident, and an
engine restart replays the queue in a different admission order.

Key derivation (mirrors the SR stream discipline of ``repro.kernels.sr``):

    request key   = fold_in(PRNGKey(engine seed), request id)
    token noise   = threefry2x32(key words,
                                 counter0 = generated-token index,
                                 counter1 = STREAM_SAMPLE)
    logit uniform = threefry2x32(token noise words,
                                 counter0 = vocab index, counter1 = 0)

Because the counters are (token index, vocab index) — never the slot id,
batch position, or wall-clock step — the sampled stream for a request is a
pure function of (engine seed, request id, model state).  Reshuffling slots
or restarting the engine replays the identical tokens (test-enforced).

Sampling itself is the Gumbel-max trick: argmax(logits/T + G) over the
top-k support.  Temperature 0 short-circuits to plain argmax (greedy), and
``top_k`` 0 means the full vocabulary.  Top-k is per-slot *dynamic* (no
static-k ``lax.top_k``): the k-th largest logit is found by sorting once,
and ties at the threshold are all admitted.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.sr import STREAM_SAMPLE, key_words, threefry2x32, uniform_from_bits

__all__ = ["request_key_words", "sample_tokens", "STREAM_SAMPLE"]

_TINY = 1e-12


def request_key_words(seed: int, rid) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The two uint32 key words for one request's sampling stream.

    ``rid`` may be a python int or an int array (vmapped fold-in); the words
    depend only on (seed, rid) — the slot-order-invariance anchor.
    """
    base = jax.random.PRNGKey(seed)
    rid = jnp.asarray(rid, jnp.uint32)
    if rid.ndim == 0:
        return key_words(jax.random.fold_in(base, rid))
    return jax.vmap(lambda r: key_words(jax.random.fold_in(base, r)))(rid)


def sample_tokens(
    logits: jnp.ndarray,       # (B, V) fp32
    kw: jnp.ndarray,           # (B, 2) uint32 per-slot request key words
    gen_idx: jnp.ndarray,      # (B,) int32 — index of the token being sampled
    temperature: jnp.ndarray,  # (B,) fp32; <= 0 means greedy
    top_k: jnp.ndarray,        # (B,) int32; <= 0 means full vocab
) -> jnp.ndarray:
    """Sample one token per slot. Jittable; returns (B,) int32."""
    B, V = logits.shape
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    # Per-(request, token) subkey, then per-logit uniforms: counter is the
    # vocab index, so the draw is independent of batch layout.
    tk0, tk1 = threefry2x32(
        kw[:, 0], kw[:, 1], gen_idx.astype(jnp.uint32), jnp.uint32(STREAM_SAMPLE)
    )
    vocab = jnp.arange(V, dtype=jnp.uint32)[None, :]
    bits, _ = threefry2x32(tk0[:, None], tk1[:, None], vocab, jnp.uint32(0))
    u = uniform_from_bits(bits)                      # (B, V) in [0, 1)
    gumbel = -jnp.log(-jnp.log(u + _TINY) + _TINY)

    temp = jnp.maximum(temperature, _TINY)[:, None]
    scaled = logits / temp

    # Dynamic per-slot top-k: threshold at the k-th largest logit (ties in).
    sorted_desc = jnp.sort(logits, axis=-1)[:, ::-1]
    k_idx = jnp.clip(top_k - 1, 0, V - 1)
    kth = sorted_desc[jnp.arange(B), k_idx]          # (B,)
    allowed = (top_k[:, None] <= 0) | (logits >= kth[:, None])

    noisy = jnp.where(allowed, scaled + gumbel, -jnp.inf)
    sampled = jnp.argmax(noisy, axis=-1).astype(jnp.int32)
    return jnp.where(temperature > 0, sampled, greedy)
