"""Transformer blocks: dense, MoE, mLSTM, sLSTM, hymba (parallel attn+SSM),
whisper encoder/decoder. One init/apply pair per kind, dispatched by
``LayerSpec.kind``; every init returns (params, axes) for the sharding rules.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import gla as gla_lib
from repro.models.attention import KVCache
from repro.models.gla import GLAState, SLSTMState
from repro.models.layers import (
    COMPUTE_DTYPE,
    INIT_STD,
    init_layernorm,
    init_rmsnorm,
    layernorm,
    mrope,
    rmsnorm,
    rope,
    rope_half,
)
from repro.models.moe import init_moe, moe_apply

__all__ = ["LayerSpec", "init_block", "apply_block", "init_block_cache"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str = "dense"   # dense | moe | mlstm | slstm | hymba | enc | dec
    window: int = 0       # 0 = full attention; >0 = sliding window


def _norm_init(cfg):
    if cfg.norm_type == "layernorm":
        return init_layernorm(cfg.d_model)
    return init_rmsnorm(cfg.d_model)


def _norm_apply(cfg, x, p):
    if cfg.norm_type == "layernorm":
        return layernorm(x, p)
    return rmsnorm(x, p)


def _rope_apply(cfg, x, positions):
    if cfg.rope_variant == "none":
        return x
    if cfg.rope_variant == "rope2d":
        return rope_half(x, positions, cfg.rope_theta)
    if cfg.rope_variant == "mrope":
        return mrope(x, positions, cfg.mrope_sections, cfg.rope_theta)
    return rope(x, positions, cfg.rope_theta)


# ---------------------------------------------------------------------------
# attention sub-module
# ---------------------------------------------------------------------------


def init_attention(key, cfg, cross: bool = False):
    D, Hq, Hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    params = {
        "wq": jax.random.normal(ks[0], (D, Hq, dh), jnp.float32) * INIT_STD,
        "wk": jax.random.normal(ks[1], (D, Hkv, dh), jnp.float32) * INIT_STD,
        "wv": jax.random.normal(ks[2], (D, Hkv, dh), jnp.float32) * INIT_STD,
        "wo": jax.random.normal(ks[3], (Hq, dh, D), jnp.float32) * INIT_STD,
    }
    axes = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if cfg.qk_norm and not cross:
        params["q_norm"] = jnp.ones((dh,), jnp.float32)
        params["k_norm"] = jnp.ones((dh,), jnp.float32)
        axes["q_norm"] = ("head_dim",)
        axes["k_norm"] = ("head_dim",)
    return params, axes


def _qk_normalize(x, scale):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + 1e-6) * scale).astype(x.dtype)


def apply_attention(
    p,
    x: jnp.ndarray,
    cfg,
    *,
    window: int = 0,
    causal: bool = True,
    positions=None,           # (B, S) or (3, B, S) for mrope; None = no rope
    kv_source: Optional[jnp.ndarray] = None,  # cross-attention source
    cache: Optional[KVCache] = None,
    cur_pos: Optional[jnp.ndarray] = None,    # (B,) decode position
    kv_lengths: Optional[jnp.ndarray] = None,  # (B,) prefill prompt lengths
):
    """Returns (out, new_cache).

    Three cache regimes: ``cache + cur_pos`` with a single-token input is a
    decode step (circular write + position-masked attention);
    ``cache + kv_lengths`` with a full sequence is a one-shot prefill (the
    forward runs as train attention and the whole K/V sequence is written
    into the cache in one gather); cache-less calls are plain training.
    """
    cd = COMPUTE_DTYPE
    src = x if kv_source is None else kv_source
    q = jnp.einsum("bsd,dhe->bshe", x.astype(cd), p["wq"].astype(cd))
    if "q_norm" in p:
        q = _qk_normalize(q, p["q_norm"])

    decode = cache is not None and cur_pos is not None and x.shape[1] == 1
    if kv_source is None or not decode:
        k = jnp.einsum("bsd,dhe->bshe", src.astype(cd), p["wk"].astype(cd))
        v = jnp.einsum("bsd,dhe->bshe", src.astype(cd), p["wv"].astype(cd))
        if "k_norm" in p:
            k = _qk_normalize(k, p["k_norm"])
    else:
        k = v = None  # cross-attention decode uses the cached projections

    if positions is not None and kv_source is None:
        q = _rope_apply(cfg, q, positions)
        k = _rope_apply(cfg, k, positions)
    elif positions is not None and kv_source is not None:
        q = _rope_apply(cfg, q, positions)

    new_cache = cache
    if decode:
        if kv_source is None:
            new_cache = attn_lib.cache_update(cache, k, v, cur_pos)
            out = attn_lib.decode_attention(
                q, new_cache, cur_pos, window=window,
                softcap_val=cfg.attn_softcap, k_chunk=cfg.decode_k_chunk,
                unroll=cfg.unroll_scans,
            )
        else:
            # cross-attention: cache holds the full encoder K/V (always valid)
            out = attn_lib.decode_attention(
                q, cache, jnp.full_like(cur_pos, 2**30), window=0,
                softcap_val=cfg.attn_softcap, k_chunk=cfg.decode_k_chunk,
                unroll=cfg.unroll_scans,
            )
    else:
        out = attn_lib.train_attention(
            q, k, v, causal=causal, window=window,
            softcap_val=cfg.attn_softcap,
            q_chunk=cfg.attn_q_chunk, k_chunk=cfg.attn_k_chunk,
            unroll=cfg.unroll_scans,
        )
        if cache is not None and kv_source is None and kv_lengths is not None:
            # one-shot prefill: park the whole (post-rope) K/V sequence in
            # the decode cache; right-padded tails stay unwritten (pos -1)
            new_cache = attn_lib.cache_prefill(cache, k, v, kv_lengths)
    y = jnp.einsum("bshe,hed->bsd", out.astype(cd), p["wo"].astype(cd))
    return y, new_cache


# ---------------------------------------------------------------------------
# MLP sub-module
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, gated: bool = True):
    ks = jax.random.split(key, 3)
    params = {
        "w1": jax.random.normal(ks[0], (d_model, d_ff), jnp.float32) * INIT_STD,
        "w2": jax.random.normal(ks[1], (d_ff, d_model), jnp.float32) * INIT_STD,
    }
    axes = {"w1": ("embed", "mlp"), "w2": ("mlp", "embed")}
    if gated:
        params["w3"] = jax.random.normal(ks[2], (d_model, d_ff), jnp.float32) * INIT_STD
        axes["w3"] = ("embed", "mlp")
    return params, axes


def apply_mlp(p, x, act: str = "silu"):
    cd = COMPUTE_DTYPE
    h = jnp.einsum("bsd,df->bsf", x.astype(cd), p["w1"].astype(cd))
    a = jax.nn.silu(h) if act == "silu" else jax.nn.gelu(h)
    if "w3" in p:
        a = a * jnp.einsum("bsd,df->bsf", x.astype(cd), p["w3"].astype(cd))
    return jnp.einsum("bsf,fd->bsd", a, p["w2"].astype(cd))


# ---------------------------------------------------------------------------
# block kinds
# ---------------------------------------------------------------------------


def _init_dense(key, cfg):
    k1, k2 = jax.random.split(key)
    attn_p, attn_a = init_attention(k1, cfg)
    mlp_p, mlp_a = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.gated_mlp)
    n1, n1a = _norm_init(cfg)
    n2, n2a = _norm_init(cfg)
    params = {"attn": attn_p, "mlp": mlp_p, "norm1": n1, "norm2": n2}
    axes = {"attn": attn_a, "mlp": mlp_a, "norm1": n1a, "norm2": n2a}
    if cfg.sandwich_norm:
        for name in ("post1", "post2"):
            p_, a_ = _norm_init(cfg)
            params[name] = p_
            axes[name] = a_
    return params, axes


def _apply_dense(p, x, spec, cfg, *, positions, cache, cur_pos, enc_out=None,
                 kv_lengths=None):
    h, new_cache = apply_attention(
        p["attn"], _norm_apply(cfg, x, p["norm1"]), cfg,
        window=spec.window, positions=positions, cache=cache, cur_pos=cur_pos,
        kv_lengths=kv_lengths,
    )
    if cfg.sandwich_norm:
        h = _norm_apply(cfg, h, p["post1"])
    x = x + h
    h2 = apply_mlp(p["mlp"], _norm_apply(cfg, x, p["norm2"]), cfg.act)
    if cfg.sandwich_norm:
        h2 = _norm_apply(cfg, h2, p["post2"])
    return x + h2, new_cache, jnp.float32(0.0)


def _init_moe(key, cfg):
    k1, k2 = jax.random.split(key)
    attn_p, attn_a = init_attention(k1, cfg)
    moe_p, moe_a = init_moe(k2, cfg.d_model, cfg.d_ff, cfg.num_experts)
    n1, n1a = _norm_init(cfg)
    n2, n2a = _norm_init(cfg)
    return (
        {"attn": attn_p, "moe": moe_p, "norm1": n1, "norm2": n2},
        {"attn": attn_a, "moe": moe_a, "norm1": n1a, "norm2": n2a},
    )


def _apply_moe(p, x, spec, cfg, *, positions, cache, cur_pos, enc_out=None,
               kv_lengths=None):
    h, new_cache = apply_attention(
        p["attn"], _norm_apply(cfg, x, p["norm1"]), cfg,
        window=spec.window, positions=positions, cache=cache, cur_pos=cur_pos,
        kv_lengths=kv_lengths,
    )
    x = x + h
    out, aux = moe_apply(
        p["moe"], _norm_apply(cfg, x, p["norm2"]),
        top_k=cfg.top_k, group_size=cfg.moe_group_size,
    )
    return x + out, new_cache, aux


def _init_mlstm(key, cfg):
    D = cfg.d_model
    Di = D  # inner dim (projection factor folded into q/k/v dims)
    H, dh = cfg.num_heads, D // cfg.num_heads
    ks = jax.random.split(key, 8)
    params = {
        "w_in": jax.random.normal(ks[0], (D, 2 * Di), jnp.float32) * INIT_STD,
        "wq": jax.random.normal(ks[1], (Di, H, dh), jnp.float32) * INIT_STD,
        "wk": jax.random.normal(ks[2], (Di, H, dh), jnp.float32) * INIT_STD,
        "wv": jax.random.normal(ks[3], (Di, H, dh), jnp.float32) * INIT_STD,
        "w_if": jax.random.normal(ks[4], (Di, 2 * H), jnp.float32) * INIT_STD,
        "b_if": jnp.concatenate(
            [jnp.zeros((H,)), jnp.full((H,), 3.0)]  # forget-gate bias ~ keep
        ).astype(jnp.float32),
        "w_out": jax.random.normal(ks[5], (Di, D), jnp.float32) * INIT_STD,
    }
    n1, n1a = _norm_init(cfg)
    params["norm"] = n1
    axes = {
        "w_in": ("embed", "mlp"),
        "wq": ("mlp", "heads", "head_dim"),
        "wk": ("mlp", "heads", "head_dim"),
        "wv": ("mlp", "heads", "head_dim"),
        "w_if": ("mlp", "heads"),
        "b_if": ("heads",),
        "w_out": ("mlp", "embed"),
        "norm": n1a,
    }
    return params, axes


def _apply_mlstm(p, x, spec, cfg, *, positions, cache, cur_pos, enc_out=None,
                 kv_lengths=None):
    cd = COMPUTE_DTYPE
    D = cfg.d_model
    H, dh = cfg.num_heads, D // cfg.num_heads
    h = _norm_apply(cfg, x, p["norm"])
    up = jnp.einsum("bsd,de->bse", h.astype(cd), p["w_in"].astype(cd))
    xm, z = jnp.split(up, 2, axis=-1)
    q = jnp.einsum("bse,ehd->bshd", xm, p["wq"].astype(cd))
    k = jnp.einsum("bse,ehd->bshd", xm, p["wk"].astype(cd)) / jnp.sqrt(float(dh))
    v = jnp.einsum("bse,ehd->bshd", xm, p["wv"].astype(cd))
    gates = jnp.einsum("bse,eh->bsh", xm, p["w_if"].astype(cd)).astype(jnp.float32)
    gates = gates + p["b_if"]
    i_gate, f_gate = jnp.split(gates, 2, axis=-1)
    log_a = jax.nn.log_sigmoid(f_gate)           # (B, S, H)
    k = k * jax.nn.sigmoid(i_gate)[..., None]    # fold input gate into k

    if kv_lengths is not None and x.shape[1] > 1:
        # right-padded prefill: an identity recurrence step is a_t = 1,
        # k_t = 0, so padded steps carry S/n through exactly
        step_ok = jnp.arange(x.shape[1])[None, :] < kv_lengths[:, None]
        log_a = jnp.where(step_ok[..., None], log_a, 0.0)
        k = jnp.where(step_ok[..., None, None], k, 0.0)

    if cache is not None and x.shape[1] == 1:
        y, new_state = gla_lib.gla_decode_step(q, k, v, log_a, cache)
    else:
        y, new_state = gla_lib.gla_chunked(
            q, k, v, log_a, chunk=cfg.gla_chunk, init_state=cache,
            unroll=cfg.unroll_scans,
        )
    y = y.reshape(*y.shape[:2], -1)              # (B, S, Di)
    out = jnp.einsum(
        "bse,ed->bsd", (y * jax.nn.silu(z)).astype(cd), p["w_out"].astype(cd)
    )
    return x + out, new_state, jnp.float32(0.0)


def _init_slstm(key, cfg):
    D = cfg.d_model
    H = cfg.num_heads
    dh = D // H
    ks = jax.random.split(key, 4)
    f_ff = int(round(4 * D / 3 / 128)) * 128
    mlp_p, mlp_a = init_mlp(ks[2], D, max(f_ff, 128), gated=True)
    n1, n1a = _norm_init(cfg)
    n2, n2a = _norm_init(cfg)
    params = {
        "w_gates": jax.random.normal(ks[0], (D, 4, D), jnp.float32) * INIT_STD,
        "r_gates": jax.random.normal(ks[1], (H, 4, dh, dh), jnp.float32) * INIT_STD,
        "w_out": jax.random.normal(ks[3], (D, D), jnp.float32) * INIT_STD,
        "mlp": mlp_p,
        "norm1": n1,
        "norm2": n2,
    }
    axes = {
        "w_gates": ("embed", "gates", "mlp"),
        "r_gates": ("heads", "gates", "head_dim", "head_dim"),
        "w_out": ("mlp", "embed"),
        "mlp": mlp_a,
        "norm1": n1a,
        "norm2": n2a,
    }
    return params, axes


def _apply_slstm(p, x, spec, cfg, *, positions, cache, cur_pos, enc_out=None,
                 kv_lengths=None):
    cd = COMPUTE_DTYPE
    h = _norm_apply(cfg, x, p["norm1"])
    gates_x = jnp.einsum("bsd,dge->bsge", h.astype(cd), p["w_gates"].astype(cd))
    step_mask = None
    if kv_lengths is not None and x.shape[1] > 1:
        step_mask = jnp.arange(x.shape[1])[None, :] < kv_lengths[:, None]
    hs, new_state = gla_lib.slstm_scan(
        gates_x, p["r_gates"], cfg.num_heads, init_state=cache,
        step_mask=step_mask,
    )
    out = jnp.einsum("bsd,de->bse", hs.astype(cd), p["w_out"].astype(cd))
    x = x + out
    x = x + apply_mlp(p["mlp"], _norm_apply(cfg, x, p["norm2"]), cfg.act)
    return x, new_state, jnp.float32(0.0)


def _init_hymba(key, cfg):
    D = cfg.d_model
    H = cfg.num_heads
    dh = D // H
    st = cfg.ssm_state
    ks = jax.random.split(key, 10)
    attn_p, attn_a = init_attention(ks[0], cfg)
    mlp_p, mlp_a = init_mlp(ks[1], D, cfg.d_ff, cfg.gated_mlp)
    n1, n1a = _norm_init(cfg)
    n2, n2a = _norm_init(cfg)
    params = {
        "attn": attn_p,
        "mlp": mlp_p,
        "norm1": n1,
        "norm2": n2,
        "ssm_in": jax.random.normal(ks[2], (D, 2 * D), jnp.float32) * INIT_STD,
        "ssm_dt": jax.random.normal(ks[3], (D, H), jnp.float32) * INIT_STD,
        "ssm_dt_bias": jnp.full((H,), -2.0, jnp.float32),
        "ssm_B": jax.random.normal(ks[4], (D, H, st), jnp.float32) * INIT_STD,
        "ssm_C": jax.random.normal(ks[5], (D, H, st), jnp.float32) * INIT_STD,
        "ssm_A_log": jnp.zeros((H,), jnp.float32),
        "ssm_D": jnp.ones((H,), jnp.float32),
        "ssm_out": jax.random.normal(ks[6], (D, D), jnp.float32) * INIT_STD,
        "scale_attn": jnp.ones((D,), jnp.float32),
        "scale_ssm": jnp.ones((D,), jnp.float32),
    }
    axes = {
        "attn": attn_a,
        "mlp": mlp_a,
        "norm1": n1a,
        "norm2": n2a,
        "ssm_in": ("embed", "mlp"),
        "ssm_dt": ("embed", "heads"),
        "ssm_dt_bias": ("heads",),
        "ssm_B": ("embed", "heads", "state"),
        "ssm_C": ("embed", "heads", "state"),
        "ssm_A_log": ("heads",),
        "ssm_D": ("heads",),
        "ssm_out": ("mlp", "embed"),
        "scale_attn": ("embed",),
        "scale_ssm": ("embed",),
    }
    return params, axes


def _apply_hymba(p, x, spec, cfg, *, positions, cache, cur_pos, enc_out=None,
                 kv_lengths=None):
    """Parallel attention + Mamba/SSD heads, outputs averaged (Hymba)."""
    cd = COMPUTE_DTYPE
    D, H = cfg.d_model, cfg.num_heads
    dh = D // H
    h = _norm_apply(cfg, x, p["norm1"])
    cache = cache or {"attn": None, "ssm": None}

    a_out, new_kv = apply_attention(
        p["attn"], h, cfg, window=spec.window, positions=positions,
        cache=cache["attn"], cur_pos=cur_pos, kv_lengths=kv_lengths,
    )

    up = jnp.einsum("bsd,de->bse", h.astype(cd), p["ssm_in"].astype(cd))
    xm, z = jnp.split(up, 2, axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", xm, p["ssm_dt"].astype(cd)).astype(jnp.float32)
        + p["ssm_dt_bias"]
    )                                             # (B, S, H)
    log_a = -dt * jnp.exp(p["ssm_A_log"])         # <= 0
    k = jnp.einsum("bsd,dhn->bshn", xm, p["ssm_B"].astype(cd))
    q = jnp.einsum("bsd,dhn->bshn", xm, p["ssm_C"].astype(cd))
    v = xm.reshape(*xm.shape[:2], H, dh) * dt[..., None].astype(cd)

    if kv_lengths is not None and x.shape[1] > 1:
        # padded prefill: a_t = 1, k_t = 0 makes the step an exact identity
        step_ok = jnp.arange(x.shape[1])[None, :] < kv_lengths[:, None]
        log_a = jnp.where(step_ok[..., None], log_a, 0.0)
        k = jnp.where(step_ok[..., None, None], k, 0.0)

    if cache["ssm"] is not None and x.shape[1] == 1:
        y, new_ssm = gla_lib.gla_decode_step(q, k, v, log_a, cache["ssm"], normalize=False)
    else:
        y, new_ssm = gla_lib.gla_chunked(
            q, k, v, log_a, chunk=cfg.gla_chunk, normalize=False,
            init_state=cache["ssm"], unroll=cfg.unroll_scans,
        )
    y = y + p["ssm_D"][None, None, :, None].astype(y.dtype) * v
    y = (y.reshape(*y.shape[:2], -1) * jax.nn.silu(z)).astype(cd)
    s_out = jnp.einsum("bse,ed->bsd", y, p["ssm_out"].astype(cd))

    combined = 0.5 * (
        a_out * p["scale_attn"].astype(cd) + s_out * p["scale_ssm"].astype(cd)
    )
    x = x + combined
    x = x + apply_mlp(p["mlp"], _norm_apply(cfg, x, p["norm2"]), cfg.act)
    return x, {"attn": new_kv, "ssm": new_ssm}, jnp.float32(0.0)


def _init_enc(key, cfg):
    k1, k2 = jax.random.split(key)
    attn_p, attn_a = init_attention(k1, cfg)
    mlp_p, mlp_a = init_mlp(k2, cfg.d_model, cfg.d_ff, gated=False)
    n1, n1a = _norm_init(cfg)
    n2, n2a = _norm_init(cfg)
    return (
        {"attn": attn_p, "mlp": mlp_p, "norm1": n1, "norm2": n2},
        {"attn": attn_a, "mlp": mlp_a, "norm1": n1a, "norm2": n2a},
    )


def _apply_enc(p, x, spec, cfg, *, positions, cache, cur_pos, enc_out=None,
               kv_lengths=None):
    h, _ = apply_attention(
        p["attn"], _norm_apply(cfg, x, p["norm1"]), cfg,
        window=0, causal=False, positions=None,
    )
    x = x + h
    x = x + apply_mlp(p["mlp"], _norm_apply(cfg, x, p["norm2"]), act="gelu")
    return x, None, jnp.float32(0.0)


def _init_dec(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    self_p, self_a = init_attention(k1, cfg)
    cross_p, cross_a = init_attention(k2, cfg, cross=True)
    mlp_p, mlp_a = init_mlp(k3, cfg.d_model, cfg.d_ff, gated=False)
    n1, n1a = _norm_init(cfg)
    n2, n2a = _norm_init(cfg)
    n3, n3a = _norm_init(cfg)
    return (
        {"self": self_p, "cross": cross_p, "mlp": mlp_p,
         "norm1": n1, "norm2": n2, "norm3": n3},
        {"self": self_a, "cross": cross_a, "mlp": mlp_a,
         "norm1": n1a, "norm2": n2a, "norm3": n3a},
    )


def _apply_dec(p, x, spec, cfg, *, positions, cache, cur_pos, enc_out=None,
               kv_lengths=None):
    cache = cache or {"self": None, "cross": None}
    h, new_self = apply_attention(
        p["self"], _norm_apply(cfg, x, p["norm1"]), cfg,
        window=spec.window, positions=None, cache=cache["self"], cur_pos=cur_pos,
        kv_lengths=kv_lengths,
    )
    x = x + h
    h, _ = apply_attention(
        p["cross"], _norm_apply(cfg, x, p["norm2"]), cfg,
        window=0, causal=False, positions=None,
        kv_source=enc_out, cache=cache["cross"], cur_pos=cur_pos,
    )
    x = x + h
    x = x + apply_mlp(p["mlp"], _norm_apply(cfg, x, p["norm3"]), act="gelu")
    return x, {"self": new_self, "cross": cache["cross"]}, jnp.float32(0.0)


_INIT = {
    "dense": _init_dense,
    "moe": _init_moe,
    "mlstm": _init_mlstm,
    "slstm": _init_slstm,
    "hymba": _init_hymba,
    "enc": _init_enc,
    "dec": _init_dec,
}
_APPLY = {
    "dense": _apply_dense,
    "moe": _apply_moe,
    "mlstm": _apply_mlstm,
    "slstm": _apply_slstm,
    "hymba": _apply_hymba,
    "enc": _apply_enc,
    "dec": _apply_dec,
}


def init_block(key, cfg, kind: str):
    return _INIT[kind](key, cfg)


def apply_block(params, x, spec: LayerSpec, cfg, **kw):
    return _APPLY[spec.kind](params, x, spec, cfg, **kw)


def init_block_cache(cfg, spec: LayerSpec, batch: int, s_max: int):
    """Decode-time cache for one block. Windowed layers allocate only
    ``window`` slots (what bounds the long_500k memory for SWA archs)."""
    D, H = cfg.d_model, cfg.num_heads
    dh_model = D // H

    def kv():
        slots = min(s_max, spec.window) if spec.window > 0 else s_max
        # decode_attention scans in chunks of 1024; keep slot count aligned
        slots = max(256, slots)
        if slots % 256:
            slots += 256 - slots % 256
        return attn_lib.make_cache(batch, slots, cfg.num_kv_heads, cfg.head_dim)

    if spec.kind in ("dense", "moe"):
        return kv()
    if spec.kind == "mlstm":
        return GLAState(
            S=jnp.zeros((batch, H, dh_model, dh_model), jnp.float32),
            n=jnp.zeros((batch, H, dh_model), jnp.float32),
        )
    if spec.kind == "slstm":
        z = jnp.zeros((batch, D), jnp.float32)
        return SLSTMState(z, z, z, jnp.full((batch, D), -1e30, jnp.float32))
    if spec.kind == "hymba":
        return {
            "attn": kv(),
            "ssm": GLAState(
                S=jnp.zeros((batch, H, cfg.ssm_state, dh_model), jnp.float32),
                n=jnp.zeros((batch, H, cfg.ssm_state), jnp.float32),
            ),
        }
    if spec.kind == "dec":
        return {"self": kv(), "cross": None}  # cross filled at prefill
    return None
