"""Model zoo: one composable stack covering the 10 assigned architectures."""

from repro.models.blocks import LayerSpec
from repro.models.model import (
    ModelConfig,
    decode_step,
    forward_hidden,
    init_model,
    init_serve_cache,
    loss_fn,
    plan_scan_units,
    prefill,
    prefill_with_cache,
)

__all__ = [
    "LayerSpec",
    "ModelConfig",
    "init_model",
    "loss_fn",
    "forward_hidden",
    "prefill",
    "prefill_with_cache",
    "decode_step",
    "init_serve_cache",
    "plan_scan_units",
]
