"""Blockwise (flash-style) attention with GQA, windows, softcap, qk-norm.

Two entry points:

* ``train_attention`` — self/cross attention over full sequences. Runs an
  online-softmax scan over *static* (q-chunk, k-chunk) block pairs; for
  causal/windowed layouts the pair list is pruned at trace time, so no FLOPs
  are spent on fully-masked blocks and the S×S logit matrix never
  materializes (required for the 32k shapes).
* ``decode_attention`` — one query step against a (possibly circular) KV
  cache, scanning k chunks with dynamic position masks.

GQA: q heads are grouped per kv head; kv heads are never replicated in
memory — the grouping happens in the einsum index structure.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["train_attention", "decode_attention", "KVCache", "cache_prefill"]

NEG_INF = -1e30


class KVCache(NamedTuple):
    """Circular KV cache. ``pos`` holds the absolute position stored in each
    slot (-1 = empty). Windowed layers allocate only ``window`` slots."""

    k: jnp.ndarray    # (B, Smax, Hkv, D)
    v: jnp.ndarray    # (B, Smax, Hkv, D)
    pos: jnp.ndarray  # (B, Smax) int32, absolute positions, -1 empty


def _split_heads(q, n_kv):
    """(B, S, Hq, D) -> (B, S, Hkv, G, D)."""
    B, S, Hq, D = q.shape
    return q.reshape(B, S, n_kv, Hq // n_kv, D)


def _block_pairs(nq: int, nk: int, qc: int, kc: int, causal: bool, window: int):
    """Static list of (iq, jk) chunk pairs that can contain unmasked entries
    (assumes positions are 0..S-1 in order — training layout)."""
    pairs = []
    for iq in range(nq):
        q_lo, q_hi = iq * qc, (iq + 1) * qc - 1
        for jk in range(nk):
            k_lo, k_hi = jk * kc, (jk + 1) * kc - 1
            if causal and k_lo > q_hi:
                continue  # entirely in the future
            if window > 0 and k_hi < q_lo - window + 1:
                continue  # entirely behind the window
            pairs.append((iq, jk))
    return pairs


def train_attention(
    q: jnp.ndarray,   # (B, Sq, Hq, D)
    k: jnp.ndarray,   # (B, Sk, Hkv, D)
    v: jnp.ndarray,   # (B, Sk, Hkv, D)
    *,
    causal: bool = True,
    window: int = 0,          # static; 0 = unbounded
    softcap_val: float = 0.0,
    q_chunk: int = 512,
    k_chunk: int = 1024,
    unroll: bool = False,     # python loop (roofline probes: honest op counts)
) -> jnp.ndarray:
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)

    qc = min(q_chunk, Sq)
    kc = min(k_chunk, Sk)
    pad_q = (-Sq) % qc
    pad_k = (-Sk) % kc
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v
    nq, nk = qp.shape[1] // qc, kp.shape[1] // kc

    qg = _split_heads(qp, Hkv)  # (B, Sq', Hkv, G, D)
    pairs = _block_pairs(nq, nk, qc, kc, causal, window)

    def make_body(iq: int, qs):
        """Online-softmax step for a fixed q chunk: carry is CHUNK-LOCAL
        (B, qc, Hkv, G[, D]) — the flash-attention structure. Keeping the
        carry chunk-local (not full-sequence) bounds the backward residuals
        (see EXPERIMENTS.md §Perf, chatglm3 hillclimb)."""

        def body(carry, jk):
            m, l, acc = carry
            ks = jax.lax.dynamic_slice_in_dim(kp, jk * kc, kc, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(vp, jk * kc, kc, axis=1)

            s = jnp.einsum(
                "bqhgd,bkhd->bqhgk", qs.astype(jnp.float32), ks.astype(jnp.float32)
            ) * scale
            if softcap_val > 0:
                s = softcap_val * jnp.tanh(s / softcap_val)

            q_pos = iq * qc + jnp.arange(qc)
            k_pos = jk * kc + jnp.arange(kc)
            ok = jnp.ones((qc, kc), bool)
            if causal:
                ok &= k_pos[None, :] <= q_pos[:, None]
            if window > 0:
                ok &= k_pos[None, :] > q_pos[:, None] - window
            ok &= (k_pos < Sk)[None, :]  # padding mask
            s = jnp.where(ok[None, :, None, None, :], s, NEG_INF)

            s_max = jnp.max(s, axis=-1)  # (B, qc, Hkv, G)
            m_new = jnp.maximum(m, s_max)
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bqhgk,bkhd->bqhgd", p, vs.astype(jnp.float32))
            a_new = acc * corr[..., None] + pv
            return (m_new, l_new, a_new), None

        return body

    chunk_outs = []
    for iq in range(nq):
        jks = [jk for (i, jk) in pairs if i == iq]
        if not jks:
            chunk_outs.append(jnp.zeros((B, qc, Hkv, G, D), jnp.float32))
            continue
        qs = jax.lax.slice_in_dim(qg, iq * qc, (iq + 1) * qc, axis=1)
        m0 = jnp.full((B, qc, Hkv, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, qc, Hkv, G), jnp.float32)
        a0 = jnp.zeros((B, qc, Hkv, G, D), jnp.float32)
        body = make_body(iq, qs)
        if unroll:
            carry = (m0, l0, a0)
            for jk in jks:
                carry, _ = body(carry, jnp.int32(jk))
            m, l, acc = carry
        else:
            # flash-attention backward: block probs recomputed, never saved
            (m, l, acc), _ = jax.lax.scan(
                jax.checkpoint(body), (m0, l0, a0), jnp.asarray(jks, jnp.int32)
            )
        chunk_outs.append(acc / jnp.maximum(l, 1e-30)[..., None])

    out = jnp.concatenate(chunk_outs, axis=1)
    out = out.reshape(B, qp.shape[1], Hq, D)[:, :Sq]
    return out.astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,        # (B, 1, Hq, D)
    cache: KVCache,
    cur_pos: jnp.ndarray,  # (B,) absolute position of the query token
    *,
    window: int = 0,
    softcap_val: float = 0.0,
    k_chunk: int = 1024,
    unroll: bool = False,
) -> jnp.ndarray:
    B, _, Hq, D = q.shape
    Smax, Hkv = cache.k.shape[1], cache.k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    kc = min(k_chunk, Smax)
    assert Smax % kc == 0, (Smax, kc)
    nk = Smax // kc

    qg = _split_heads(q, Hkv)[:, 0]  # (B, Hkv, G, D)

    def body(carry, jk):
        m, l, acc = carry
        ks = jax.lax.dynamic_slice_in_dim(cache.k, jk * kc, kc, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(cache.v, jk * kc, kc, axis=1)
        ps = jax.lax.dynamic_slice_in_dim(cache.pos, jk * kc, kc, axis=1)

        s = jnp.einsum(
            "bhgd,bkhd->bhgk", qg.astype(jnp.float32), ks.astype(jnp.float32)
        ) * scale
        if softcap_val > 0:
            s = softcap_val * jnp.tanh(s / softcap_val)

        ok = (ps >= 0) & (ps <= cur_pos[:, None])
        if window > 0:
            ok &= ps > (cur_pos[:, None] - window)
        s = jnp.where(ok[:, None, None, :], s, NEG_INF)

        s_max = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, s_max)
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgk,bkhd->bhgd", p, vs.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, D), jnp.float32)
    if unroll:
        carry = (m0, l0, a0)
        for jk in range(nk):
            carry, _ = body(carry, jnp.int32(jk))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(nk))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, 1, Hq, D).astype(q.dtype)


def cache_prefill(
    cache: KVCache,
    k_new: jnp.ndarray,    # (B, S, Hkv, D) — positions 0..S-1 in order
    v_new: jnp.ndarray,    # (B, S, Hkv, D)
    lengths: jnp.ndarray,  # (B,) int32 — real prompt length per row (rest pad)
) -> KVCache:
    """Write a whole (right-padded) prompt into the circular cache at once.

    Expressed as a gather, not a scatter: for each slot ``s`` the entry that
    a token-at-a-time prefill would leave behind is the *largest* position
    ``p < len`` with ``p % Smax == s`` (circular overwrite keeps the latest).
    Solving for it directly sidesteps duplicate-index scatter hazards and
    handles every per-row case uniformly — short prompts leave trailing
    slots untouched (pos stays -1 on a fresh cache), prompts longer than the
    slot count keep exactly their trailing ``Smax`` positions (what a
    windowed layer's circular cache retains anyway).
    """
    B, S = k_new.shape[:2]
    Smax = cache.k.shape[1]
    s = jnp.arange(Smax, dtype=jnp.int32)[None, :]          # (1, Smax)
    len_b = lengths.astype(jnp.int32)[:, None]              # (B, 1)
    # Largest p in [0, len) with p ≡ s (mod Smax); negative ⇒ slot unused.
    p_star = s + jnp.floor_divide(len_b - 1 - s, Smax) * Smax  # (B, Smax)
    valid = p_star >= 0
    pidx = jnp.clip(p_star, 0, S - 1)
    b_idx = jnp.arange(B)[:, None]
    k_sel = k_new[b_idx, pidx].astype(cache.k.dtype)
    v_sel = v_new[b_idx, pidx].astype(cache.v.dtype)
    k = jnp.where(valid[..., None, None], k_sel, cache.k)
    v = jnp.where(valid[..., None, None], v_sel, cache.v)
    p = jnp.where(valid, p_star, cache.pos)
    return KVCache(k, v, p)


def cache_update(cache: KVCache, k_new: jnp.ndarray, v_new: jnp.ndarray, pos: jnp.ndarray) -> KVCache:
    """Write one decode step into the circular cache. pos: (B,)."""
    Smax = cache.k.shape[1]
    slot = (pos % Smax).astype(jnp.int32)  # (B,)
    b_idx = jnp.arange(cache.k.shape[0])
    k = cache.k.at[b_idx, slot].set(k_new[:, 0].astype(cache.k.dtype))
    v = cache.v.at[b_idx, slot].set(v_new[:, 0].astype(cache.v.dtype))
    p = cache.pos.at[b_idx, slot].set(pos.astype(jnp.int32))
    return KVCache(k, v, p)


def make_cache(batch: int, s_max: int, n_kv: int, head_dim: int, dtype=jnp.bfloat16) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, s_max, n_kv, head_dim), dtype),
        v=jnp.zeros((batch, s_max, n_kv, head_dim), dtype),
        pos=jnp.full((batch, s_max), -1, jnp.int32),
    )
