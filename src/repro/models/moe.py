"""Mixture-of-Experts FFN with top-k routing and capacity-based dispatch.

t5x/flaxformer-style one-hot dispatch: tokens are grouped (group size T), each
group dispatches to per-expert capacity buffers C = T·k·cf/E, and the expert
FFNs run as batched einsums over the expert dim — which the sharding rules
place on the `model` mesh axis when E divides it (phi3.5: 16 experts) or fall
back to sharding the expert FFN hidden dim (mixtral: 8 experts, d_ff TP).
Dispatch/combine einsum overhead is ~T/(3·d_ff) of the FFN FLOPs (<10% at
T=2048), which the roofline's MODEL_FLOPS ratio makes visible.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import COMPUTE_DTYPE, INIT_STD

__all__ = ["init_moe", "moe_apply"]


def init_moe(key, d_model: int, d_ff: int, n_experts: int):
    ks = jax.random.split(key, 4)
    params = {
        "router": jax.random.normal(ks[0], (d_model, n_experts), jnp.float32) * INIT_STD,
        "w1": jax.random.normal(ks[1], (n_experts, d_model, d_ff), jnp.float32) * INIT_STD,
        "w3": jax.random.normal(ks[2], (n_experts, d_model, d_ff), jnp.float32) * INIT_STD,
        "w2": jax.random.normal(ks[3], (n_experts, d_ff, d_model), jnp.float32) * INIT_STD,
    }
    axes = {
        "router": ("embed", "experts"),
        "w1": ("experts", "embed", "mlp"),
        "w3": ("experts", "embed", "mlp"),
        "w2": ("experts", "mlp", "embed"),
    }
    return params, axes


def moe_apply(
    params,
    x: jnp.ndarray,  # (B, S, D)
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    group_size: int = 2048,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output (B,S,D), load-balance aux loss scalar)."""
    B, S, D = x.shape
    E = params["router"].shape[1]
    n_tokens = B * S
    T = min(group_size, n_tokens)
    assert n_tokens % T == 0, (n_tokens, T)
    G = n_tokens // T
    C = max(4, int(T * top_k * capacity_factor / E))
    C = min(C, T)

    xg = x.reshape(G, T, D)
    logits = jnp.einsum(
        "gtd,de->gte", xg.astype(COMPUTE_DTYPE), params["router"].astype(COMPUTE_DTYPE)
    ).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)

    top_vals, top_idx = jax.lax.top_k(probs, top_k)  # (G, T, k)
    top_vals = top_vals / jnp.sum(top_vals, axis=-1, keepdims=True)  # renorm (mixtral)

    # position-in-expert via cumulative counts, token-major priority
    onehot = jax.nn.one_hot(top_idx, E, dtype=jnp.float32)      # (G, T, k, E)
    flat = onehot.reshape(G, T * top_k, E)
    pos = jnp.cumsum(flat, axis=1) * flat - 1.0                 # (G, T*k, E), -1 if unrouted
    keep = (pos >= 0) & (pos < C)
    pos = jnp.where(keep, pos, 0.0)

    # dispatch/combine tensors (G, T, E, C) in bf16: these are the largest
    # transients in an MoE block — bf16 halves their HBM footprint and the
    # one-hot matmuls run on the MXU anyway.
    pos_onehot = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=COMPUTE_DTYPE)
    pos_onehot = pos_onehot * keep[..., None].astype(COMPUTE_DTYPE)
    pec = pos_onehot.reshape(G, T, top_k, E, C)
    dispatch = jnp.sum(pec, axis=2)                             # (G, T, E, C)
    combine = jnp.sum(
        pec * top_vals[..., None, None].astype(COMPUTE_DTYPE), axis=2
    )

    # expert FFN on capacity buffers
    exp_in = jnp.einsum("gtec,gtd->egcd", dispatch, xg.astype(COMPUTE_DTYPE))
    h = jnp.einsum("egcd,edf->egcf", exp_in, params["w1"].astype(COMPUTE_DTYPE))
    hg = jnp.einsum("egcd,edf->egcf", exp_in, params["w3"].astype(COMPUTE_DTYPE))
    h = jax.nn.silu(h) * hg
    exp_out = jnp.einsum("egcf,efd->egcd", h, params["w2"].astype(COMPUTE_DTYPE))
    out = jnp.einsum("egcd,gtec->gtd", exp_out, combine)

    # load-balance aux loss (Switch): E * mean(frac_tokens) . mean(prob)
    frac = jnp.mean(dispatch.sum(axis=-1), axis=1)              # (G, E) tokens/expert
    mean_prob = jnp.mean(probs, axis=1)                         # (G, E)
    aux = E * jnp.mean(jnp.sum(frac / T * mean_prob, axis=-1))
    return out.reshape(B, S, D).astype(x.dtype), aux
