"""Model assembly: config, scan-unit grouping, init, train/prefill/decode.

A single composable stack covers all ten assigned architectures. Layers are
grouped into *scan units*: if the layer pattern has a small period p (gemma2
local/global: p=2; xLSTM m/m/m/s: p=4) the whole stack is one `lax.scan` over
stacked parameter pytrees — the production trick that keeps HLO size and
compile time flat in depth. Aperiodic patterns (hymba's 3 global layers) fall
back to maximal homogeneous runs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import KVCache
from repro.models.blocks import (
    LayerSpec,
    apply_block,
    init_block,
    init_block_cache,
)
from repro.models.layers import (
    COMPUTE_DTYPE,
    chunked_cross_entropy,
    embed_lookup,
    init_embedding,
    init_rmsnorm,
    rmsnorm,
    init_layernorm,
    layernorm,
    sinusoidal_positions,
    softcap,
)

__all__ = [
    "ModelConfig",
    "ScanUnit",
    "init_model",
    "loss_fn",
    "prefill",
    "prefill_with_cache",
    "decode_step",
    "init_serve_cache",
]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    blocks: Tuple[LayerSpec, ...]
    encoder_blocks: Tuple[LayerSpec, ...] = ()
    num_experts: int = 0
    top_k: int = 0
    qk_norm: bool = False
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    rope_variant: str = "rope"   # rope | rope2d | mrope | none
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    norm_type: str = "rmsnorm"
    sandwich_norm: bool = False
    act: str = "silu"
    gated_mlp: bool = True
    tie_embeddings: bool = False
    ssm_state: int = 16
    gla_chunk: int = 128
    moe_group_size: int = 2048
    input_mode: str = "tokens"   # tokens | embeds (modality-stub archs)
    family: str = "decoder"      # decoder | encdec
    remat: bool = True
    # scan execution knobs (roofline probes unroll for honest op counts)
    unroll_scans: bool = False
    attn_q_chunk: int = 512
    attn_k_chunk: int = 1024
    decode_k_chunk: int = 1024
    ce_chunk: int = 512

    @property
    def sub_quadratic(self) -> bool:
        """True iff decode state is bounded (long_500k eligibility)."""
        return all(
            b.kind in ("mlstm", "slstm", "hymba") or b.window > 0
            for b in self.blocks
        )


@dataclasses.dataclass(frozen=True)
class ScanUnit:
    pattern: Tuple[LayerSpec, ...]
    repeat: int


def plan_scan_units(blocks: Tuple[LayerSpec, ...]) -> List[ScanUnit]:
    """Group layers into scan units (periodic pattern or maximal runs)."""
    L = len(blocks)
    for p in (1, 2, 3, 4):
        if L % p == 0 and L // p > 1:
            if all(blocks[i] == blocks[i % p] for i in range(L)):
                return [ScanUnit(tuple(blocks[:p]), L // p)]
    units: List[ScanUnit] = []
    i = 0
    while i < L:
        j = i
        while j < L and blocks[j] == blocks[i]:
            j += 1
        units.append(ScanUnit((blocks[i],), j - i))
        i = j
    return units


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _stack_init(key, cfg: ModelConfig, unit: ScanUnit):
    """Init one scan unit: per sub-pattern, params stacked over `repeat`."""
    params: Dict[str, Any] = {}
    axes: Dict[str, Any] = {}
    keys = jax.random.split(key, len(unit.pattern))
    for si, spec in enumerate(unit.pattern):
        layer_keys = jax.random.split(keys[si], unit.repeat)
        stacked = jax.vmap(lambda k: init_block(k, cfg, spec.kind)[0])(layer_keys)
        _, sub_axes = init_block(jax.random.PRNGKey(0), cfg, spec.kind)
        params[f"sub{si}"] = stacked
        axes[f"sub{si}"] = jax.tree_util.tree_map(
            lambda a: ("layers",) + a,
            sub_axes,
            is_leaf=lambda a: isinstance(a, tuple) and all(isinstance(s, str) for s in a),
        )
    return params, axes


def init_model(key, cfg: ModelConfig):
    """Returns (params, axes). Params are fp32 masters."""
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {}
    axes: Dict[str, Any] = {}

    emb, emb_axes = init_embedding(keys[0], cfg.vocab_size, cfg.d_model)
    params["embed"] = emb
    axes["embed"] = emb_axes

    units = plan_scan_units(cfg.blocks)
    unit_params, unit_axes = [], []
    for ui, unit in enumerate(units):
        p, a = _stack_init(jax.random.fold_in(keys[1], ui), cfg, unit)
        unit_params.append(p)
        unit_axes.append(a)
    params["decoder"] = unit_params
    axes["decoder"] = unit_axes

    if cfg.family == "encdec":
        enc_units = plan_scan_units(cfg.encoder_blocks)
        ep, ea = [], []
        for ui, unit in enumerate(enc_units):
            p, a = _stack_init(jax.random.fold_in(keys[2], ui), cfg, unit)
            ep.append(p)
            ea.append(a)
        params["encoder"] = ep
        axes["encoder"] = ea
        if cfg.norm_type == "layernorm":
            n, na = init_layernorm(cfg.d_model)
        else:
            n, na = init_rmsnorm(cfg.d_model)
        params["enc_norm"] = n
        axes["enc_norm"] = na

    if cfg.norm_type == "layernorm":
        n, na = init_layernorm(cfg.d_model)
    else:
        n, na = init_rmsnorm(cfg.d_model)
    params["final_norm"] = n
    axes["final_norm"] = na

    if not cfg.tie_embeddings:
        head = jax.random.normal(keys[3], (cfg.d_model, cfg.vocab_size), jnp.float32) * 0.02
        params["head"] = head
        axes["head"] = ("embed", "vocab")
    return params, axes


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _final_norm(cfg, x, p):
    if cfg.norm_type == "layernorm":
        return layernorm(x, p)
    return rmsnorm(x, p)


def _head_weight(cfg, params):
    return params["embed"].T if cfg.tie_embeddings else params["head"]


def _run_units(
    cfg: ModelConfig,
    units: List[ScanUnit],
    unit_params: List[Any],
    x: jnp.ndarray,
    *,
    positions,
    enc_out=None,
    caches: Optional[List[Any]] = None,
    cur_pos=None,
    kv_lengths=None,
    collect_cache: bool = False,
    unit_axes: Optional[List[Any]] = None,
):
    """Run all scan units. Returns (x, new_caches, aux_sum)."""
    from repro.sharding.context import constrain_activation, constrain_layer_params

    aux_total = jnp.float32(0.0)
    new_caches: List[Any] = []

    for ui, unit in enumerate(units):
        p_unit = unit_params[ui]
        cache_unit = caches[ui] if caches is not None else None
        a_unit = unit_axes[ui] if unit_axes is not None else None

        def body(carry, xs, _unit=unit, _axes=a_unit):
            h, aux = carry
            p_l = xs["params"]
            c_l = xs.get("cache")
            new_c = {}
            for si, spec in enumerate(_unit.pattern):
                sub_cache = c_l[f"sub{si}"] if c_l is not None else None
                p_sub = p_l[f"sub{si}"]
                if _axes is not None:
                    # in-body layout pin: keeps the backward grad accumulator
                    # in the ZeRO layout (see repro.sharding.context)
                    p_sub = constrain_layer_params(p_sub, _axes[f"sub{si}"])
                h = constrain_activation(h)
                h, nc, a = apply_block(
                    p_sub, h, spec, cfg,
                    positions=positions, cache=sub_cache, cur_pos=cur_pos,
                    enc_out=enc_out, kv_lengths=kv_lengths,
                )
                new_c[f"sub{si}"] = nc
                aux = aux + a
            out = new_c if (c_l is not None or collect_cache) else None
            return (h, aux), out

        if cfg.remat:
            body = jax.checkpoint(body)

        xs = {"params": p_unit}
        if cache_unit is not None:
            xs["cache"] = cache_unit
        (x, aux_total), cache_out = jax.lax.scan(body, (x, aux_total), xs)
        new_caches.append(cache_out)
    return x, new_caches, aux_total


def forward_hidden(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]):
    """Token/embed inputs -> final hidden states (train/prefill path)."""
    units = plan_scan_units(cfg.blocks)

    if cfg.input_mode == "embeds":
        x = batch["embeds"].astype(COMPUTE_DTYPE)
    else:
        x = embed_lookup(params["embed"], batch["tokens"])
    B, S = x.shape[0], x.shape[1]

    if cfg.rope_variant == "mrope":
        positions = batch.get("positions")
        if positions is None:
            pos1 = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
            positions = jnp.stack([pos1] * 3)
    elif cfg.rope_variant == "none":
        positions = None
        x = x + sinusoidal_positions(S, cfg.d_model)[None].astype(x.dtype)
    else:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    from repro.sharding.context import ctx_axes

    enc_out = None
    if cfg.family == "encdec":
        frames = batch["frames"].astype(COMPUTE_DTYPE)
        Se = frames.shape[1]
        e = frames + sinusoidal_positions(Se, cfg.d_model)[None].astype(frames.dtype)
        enc_units = plan_scan_units(cfg.encoder_blocks)
        e, _, _ = _run_units(cfg, enc_units, params["encoder"], e, positions=None,
                             unit_axes=ctx_axes("encoder"))
        enc_out = _final_norm(cfg, e, params["enc_norm"])

    x, _, aux = _run_units(
        cfg, units, params["decoder"], x, positions=positions, enc_out=enc_out,
        unit_axes=ctx_axes("decoder"),
    )
    x = _final_norm(cfg, x, params["final_norm"])
    return x, aux


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]):
    """Causal LM loss (chunked CE) + MoE aux. Returns (loss, metrics)."""
    x, aux = forward_hidden(params, cfg, batch)
    loss = chunked_cross_entropy(
        x, _head_weight(cfg, params), batch["labels"],
        logit_cap=cfg.final_softcap, chunk=cfg.ce_chunk,
        unroll=cfg.unroll_scans,
    )
    total = loss + 0.01 * aux
    return total, {"ce_loss": loss, "aux_loss": aux}


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------


def init_serve_cache(cfg: ModelConfig, batch: int, s_max: int):
    """Cache pytree for decode. Stacked per scan unit (matches lax.scan xs)."""
    units = plan_scan_units(cfg.blocks)
    caches = []
    for unit in units:
        unit_cache = {}
        for si, spec in enumerate(unit.pattern):
            # dec blocks recompute cross K/V from enc_out each step ("cross"
            # stays None); only self-attention KV is cached.
            one = init_block_cache(cfg, spec, batch, s_max)
            stacked = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a[None], (unit.repeat,) + a.shape), one
            )
            unit_cache[f"sub{si}"] = stacked
        caches.append(unit_cache)
    return caches


def decode_step(
    params,
    cfg: ModelConfig,
    caches: List[Any],
    tokens: jnp.ndarray,    # (B,) int32
    pos: jnp.ndarray,       # (B,) int32 absolute position
    enc_out: Optional[jnp.ndarray] = None,
):
    """One serving step: next-token logits + updated caches."""
    units = plan_scan_units(cfg.blocks)
    x = embed_lookup(params["embed"], tokens[:, None])  # (B, 1, D)
    B = x.shape[0]

    if cfg.rope_variant == "mrope":
        positions = jnp.stack([pos[None, :, None]] * 3)[:, 0]  # (3, B, 1)
    elif cfg.rope_variant == "none":
        positions = None
        from repro.models.layers import sinusoidal_at

        x = x + sinusoidal_at(pos, cfg.d_model)[:, None].astype(x.dtype)
    else:
        positions = pos[:, None]  # (B, 1)

    from repro.sharding.context import ctx_axes

    x, new_caches, _ = _run_units(
        cfg, units, params["decoder"], x,
        positions=positions, enc_out=enc_out, caches=caches, cur_pos=pos,
        unit_axes=ctx_axes("decoder"),
    )
    x = _final_norm(cfg, x, params["final_norm"])
    logits = jnp.einsum(
        "bsd,dv->bsv", x.astype(COMPUTE_DTYPE),
        _head_weight(cfg, params).astype(COMPUTE_DTYPE),
    )[:, 0].astype(jnp.float32)
    if cfg.final_softcap > 0:
        logits = softcap(logits, cfg.final_softcap)
    return logits, new_caches


def prefill(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]):
    """Prefill pass: final hidden states + last-position logits.

    (The dry-run's prefill_32k cell lowers this; cache materialization for
    chat-style serving goes through ``prefill_with_cache``.)
    """
    x, _ = forward_hidden(params, cfg, batch)
    last = x[:, -1]
    logits = jnp.einsum(
        "bd,dv->bv", last.astype(COMPUTE_DTYPE),
        _head_weight(cfg, params).astype(COMPUTE_DTYPE),
    ).astype(jnp.float32)
    if cfg.final_softcap > 0:
        logits = softcap(logits, cfg.final_softcap)
    return logits


def prefill_with_cache(
    params,
    cfg: ModelConfig,
    tokens: jnp.ndarray,   # (B, S) int32, right-padded prompts
    lengths: jnp.ndarray,  # (B,) int32 real prompt length per row
    caches: List[Any],
):
    """One-shot prompt consumption for serving (token-decoder archs only).

    Runs the full-sequence forward once over the right-padded prompt batch,
    writing K/V (attention) and carried recurrent states (mLSTM/sLSTM/SSM)
    into the decode caches, and returns the logits at each row's *last real
    token* — the distribution the first generated token is sampled from.
    Padding is inert by construction: causal attention never looks forward
    to padded keys, padded cache slots keep pos = -1, and recurrent paths
    run identity steps (a = 1, k = 0 / state freeze) on padded positions.

    Returns ``(logits (B, V) fp32, new_caches)``.
    """
    if cfg.family != "decoder" or cfg.input_mode != "tokens":
        raise ValueError("prefill_with_cache serves token-decoder archs only")
    units = plan_scan_units(cfg.blocks)
    x = embed_lookup(params["embed"], tokens)
    B, S = tokens.shape

    if cfg.rope_variant == "mrope":
        pos1 = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        positions = jnp.stack([pos1] * 3)
    elif cfg.rope_variant == "none":
        positions = None
        x = x + sinusoidal_positions(S, cfg.d_model)[None].astype(x.dtype)
    else:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    from repro.sharding.context import ctx_axes

    x, new_caches, _ = _run_units(
        cfg, units, params["decoder"], x, positions=positions,
        caches=caches, kv_lengths=lengths, unit_axes=ctx_axes("decoder"),
    )
    x = _final_norm(cfg, x, params["final_norm"])
    last = x[jnp.arange(B), jnp.maximum(lengths - 1, 0)]  # (B, D)
    logits = jnp.einsum(
        "bd,dv->bv", last.astype(COMPUTE_DTYPE),
        _head_weight(cfg, params).astype(COMPUTE_DTYPE),
    ).astype(jnp.float32)
    if cfg.final_softcap > 0:
        logits = softcap(logits, cfg.final_softcap)
    return logits, new_caches
