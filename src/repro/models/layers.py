"""Shared layers: norms, dense, embeddings, RoPE variants, chunked CE loss.

No flax — params are plain nested dicts. Every ``init_*`` returns
``(params, axes)`` where ``axes`` mirrors the params tree with tuples of
*logical* dimension names; the sharding rules engine
(repro.sharding.rules) maps logical names to mesh axes.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "init_dense",
    "dense",
    "init_rmsnorm",
    "rmsnorm",
    "init_layernorm",
    "layernorm",
    "init_embedding",
    "embed_lookup",
    "rope",
    "rope_half",
    "mrope",
    "softcap",
    "chunked_cross_entropy",
    "sinusoidal_positions",
]

INIT_STD = 0.02
COMPUTE_DTYPE = jnp.bfloat16


# ---------------------------------------------------------------------------
# dense / norms / embedding
# ---------------------------------------------------------------------------


def init_dense(key, shape: Tuple[int, ...], axes: Tuple[str, ...], scale: float = INIT_STD):
    """Weight of ``shape`` with logical ``axes`` (no bias — LLaMA-style)."""
    assert len(shape) == len(axes), (shape, axes)
    w = jax.random.normal(key, shape, jnp.float32) * scale
    return w, axes


def dense(x: jnp.ndarray, w: jnp.ndarray, spec: str) -> jnp.ndarray:
    """einsum with bf16 compute, weights cast in (fp32 master kept outside)."""
    return jnp.einsum(spec, x.astype(COMPUTE_DTYPE), w.astype(COMPUTE_DTYPE))


def init_rmsnorm(dim: int, axis: str = "embed"):
    return jnp.ones((dim,), jnp.float32), (axis,)


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * scale
    return out.astype(COMPUTE_DTYPE)


def init_layernorm(dim: int, axis: str = "embed"):
    params = {"scale": jnp.ones((dim,), jnp.float32), "bias": jnp.zeros((dim,), jnp.float32)}
    axes = {"scale": (axis,), "bias": (axis,)}
    return params, axes


def layernorm(x: jnp.ndarray, p, eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return out.astype(COMPUTE_DTYPE)


def init_embedding(key, vocab: int, dim: int):
    w = jax.random.normal(key, (vocab, dim), jnp.float32) * INIT_STD
    return w, ("vocab", "embed")


def embed_lookup(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table.astype(COMPUTE_DTYPE), ids, axis=0)


# ---------------------------------------------------------------------------
# rotary position embeddings (standard / half-dim / M-RoPE)
# ---------------------------------------------------------------------------


def _rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def _apply_rot(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """Rotate pairs split as [first half | second half] (LLaMA convention)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """Standard RoPE. x: (B, S, H, D); positions: (B, S) int32."""
    freqs = _rope_freqs(x.shape[-1], theta)  # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    return _apply_rot(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def rope_half(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """ChatGLM-style 2d RoPE: rotary applied to the first half of head_dim
    only; the second half passes through unrotated."""
    half = x.shape[-1] // 2
    rotated = rope(x[..., :half], positions, theta)
    return jnp.concatenate([rotated, x[..., half:]], axis=-1)


def mrope(
    x: jnp.ndarray,
    positions: jnp.ndarray,
    sections: Tuple[int, int, int],
    theta: float = 10000.0,
) -> jnp.ndarray:
    """Qwen2-VL M-RoPE: head_dim frequency bands split into (t, h, w)
    sections, each rotated by its own position stream.

    x: (B, S, H, D); positions: (3, B, S) — temporal/height/width ids (equal
    for pure text). sum(sections) == D // 2.
    """
    D = x.shape[-1]
    assert sum(sections) == D // 2, (sections, D)
    freqs = _rope_freqs(D, theta)  # (D/2,)
    # per-frequency section id: first sections[0] freqs use t, next use h, ...
    ang_parts = []
    start = 0
    for s, sec in enumerate(sections):
        f = freqs[start : start + sec]
        ang_parts.append(positions[s][..., None].astype(jnp.float32) * f)
        start += sec
    ang = jnp.concatenate(ang_parts, axis=-1)  # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    return _apply_rot(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def sinusoidal_positions(length: int, dim: int) -> jnp.ndarray:
    """Whisper-style fixed sinusoidal embeddings (S, D)."""
    return sinusoidal_at(jnp.arange(length, dtype=jnp.float32), dim)


def sinusoidal_at(pos: jnp.ndarray, dim: int) -> jnp.ndarray:
    """Sinusoidal embedding rows for arbitrary positions: (..., ) -> (..., D)."""
    idx = jnp.arange(dim // 2, dtype=jnp.float32)
    ang = pos.astype(jnp.float32)[..., None] / jnp.power(10000.0, 2 * idx / dim)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# misc
# ---------------------------------------------------------------------------


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def chunked_cross_entropy(
    x: jnp.ndarray,            # (B, S, D) final hidden states (bf16)
    head: jnp.ndarray,         # (D, V) output projection (fp32 master)
    labels: jnp.ndarray,       # (B, S) int32, -1 = masked
    *,
    logit_cap: float = 0.0,
    chunk: int = 512,
    unroll: bool = False,
) -> jnp.ndarray:
    """Sequence-chunked softmax CE: logits for only ``chunk`` positions are
    live at a time, so the (B, S, V) tensor never materializes. This is the
    production memory trick that keeps large-vocab archs (gemma2: 256k) inside
    HBM at 32k context."""
    B, S, D = x.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    n_chunks = x.shape[1] // chunk
    xs = x.reshape(B, n_chunks, chunk, D).swapaxes(0, 1)       # (N, B, c, D)
    ls = labels.reshape(B, n_chunks, chunk).swapaxes(0, 1)     # (N, B, c)

    def body(carry, inp):
        loss_sum, count = carry
        xc, lc = inp
        logits = jnp.einsum(
            "bcd,dv->bcv", xc.astype(COMPUTE_DTYPE), head.astype(COMPUTE_DTYPE)
        ).astype(jnp.float32)
        if logit_cap > 0:
            logits = logit_cap * jnp.tanh(logits / logit_cap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1
        )[..., 0]
        mask = (lc >= 0).astype(jnp.float32)
        loss_sum = loss_sum + jnp.sum((lse - gold) * mask)
        count = count + jnp.sum(mask)
        return (loss_sum, count), None

    if unroll:
        carry = (jnp.float32(0), jnp.float32(0))
        for i in range(n_chunks):
            carry, _ = body(carry, (xs[i], ls[i]))
        loss_sum, count = carry
    else:
        # recompute chunk logits in the backward pass (they are the largest
        # loss-path transient: B x chunk x V fp32 per scan step)
        (loss_sum, count), _ = jax.lax.scan(
            jax.checkpoint(body), (jnp.float32(0), jnp.float32(0)), (xs, ls)
        )
    return loss_sum / jnp.maximum(count, 1.0)
