"""Gated linear recurrences: the shared engine for mLSTM (xLSTM) and the
Mamba/SSD heads of hymba, plus the strictly-sequential sLSTM cell.

The unifying recurrence (per head, scalar decay a_t ∈ (0, 1]):

    S_t = a_t · S_{t-1} + k_t ⊗ v_t          (matrix state, dk × dv)
    n_t = a_t · n_{t-1} + k_t                 (normalizer, mLSTM only)
    y_t = q_t · S_t  [ / max(|q_t · n_t|, 1) ]

``gla_chunked`` evaluates it chunkwise: intra-chunk terms via a masked
quadratic in the chunk (parallel, MXU-friendly), inter-chunk via the carried
state — linear in sequence length, which is what qualifies the SSM/hybrid
archs for the long_500k shape. Decay ratios are computed in log space and
only as exp(cum_i - cum_j) with j <= i, so they are bounded by 1 (stable).

TPU adaptation note (DESIGN.md §8): we use sigmoid forget / sigmoid input
gating (GLA form) rather than xLSTM's exponential-gate + max-stabilizer; the
recurrence structure and state shapes match, which is what the optimizer
study needs.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["gla_chunked", "gla_decode_step", "GLAState", "slstm_scan"]


class GLAState(NamedTuple):
    S: jnp.ndarray  # (B, H, dk, dv)
    n: jnp.ndarray  # (B, H, dk)


def gla_chunked(
    q: jnp.ndarray,       # (B, S, H, dk)
    k: jnp.ndarray,       # (B, S, H, dk)
    v: jnp.ndarray,       # (B, S, H, dv)
    log_a: jnp.ndarray,   # (B, S, H) — log decay, <= 0
    *,
    chunk: int = 128,
    normalize: bool = True,
    init_state: Optional[GLAState] = None,
    unroll: bool = False,
) -> Tuple[jnp.ndarray, GLAState]:
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))  # pad decay 0 => a=1
    N = q.shape[1] // c

    def to_chunks(x):
        return x.reshape(B, N, c, *x.shape[2:]).swapaxes(0, 1)

    qs, ks, vs, las = map(to_chunks, (q, k, v, log_a))
    qs = qs.astype(jnp.float32)
    ks = ks.astype(jnp.float32)
    vs = vs.astype(jnp.float32)
    las = las.astype(jnp.float32)

    if init_state is None:
        S0 = jnp.zeros((B, H, dk, dv), jnp.float32)
        n0 = jnp.zeros((B, H, dk), jnp.float32)
    else:
        S0, n0 = init_state.S.astype(jnp.float32), init_state.n.astype(jnp.float32)

    tri = jnp.tril(jnp.ones((c, c), bool))  # j <= i

    def body(carry, inp):
        S_prev, n_prev = carry
        qc, kc, vc, lac = inp  # (B, c, H, *)
        cum = jnp.cumsum(lac, axis=1)            # (B, c, H) log A_i
        last = cum[:, -1]                        # (B, H)

        # inter-chunk: q_i · (A_i · S_prev)
        q_scaled = qc * jnp.exp(cum)[..., None]
        y_inter = jnp.einsum("bchk,bhkv->bchv", q_scaled, S_prev)

        # intra-chunk: (q_i · k_j) exp(cum_i - cum_j), j <= i
        scores = jnp.einsum("bchk,bdhk->bhcd", qc, kc)
        ratio = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # (B,c,c,H) i,j
        ratio = jnp.where(tri[None, :, :, None], ratio, 0.0)
        att = scores * ratio.transpose(0, 3, 1, 2)               # (B,H,c,c)
        y_intra = jnp.einsum("bhcd,bdhv->bchv", att, vc)
        y = y_inter + y_intra

        if normalize:
            n_inter = jnp.exp(cum)[..., None] * n_prev[:, None]   # (B,c,H,dk)
            n_intra = jnp.einsum("bhcd,bdhk->bchk", ratio.transpose(0, 3, 1, 2), kc)
            n_i = n_inter + n_intra
            denom = jnp.abs(jnp.einsum("bchk,bchk->bch", qc, n_i))
            y = y / jnp.maximum(denom, 1.0)[..., None]
        else:
            n_i = None

        # carry updates
        decay_to_end = jnp.exp(last[:, None] - cum)               # (B,c,H)
        S_new = jnp.exp(last)[..., None, None] * S_prev + jnp.einsum(
            "bchk,bchv->bhkv", kc * decay_to_end[..., None], vc
        )
        n_new = jnp.exp(last)[..., None] * n_prev + jnp.sum(
            kc * decay_to_end[..., None], axis=1
        )
        return (S_new, n_new), y

    if unroll:
        carry = (S0, n0)
        ys_list = []
        for i in range(N):
            carry, y = body(carry, (qs[i], ks[i], vs[i], las[i]))
            ys_list.append(y)
        (S_f, n_f), ys = carry, jnp.stack(ys_list)
    else:
        (S_f, n_f), ys = jax.lax.scan(body, (S0, n0), (qs, ks, vs, las))
    y = ys.swapaxes(0, 1).reshape(B, N * c, H, dv)[:, :S]
    return y.astype(v.dtype), GLAState(S_f, n_f)


def gla_decode_step(
    q: jnp.ndarray,      # (B, 1, H, dk)
    k: jnp.ndarray,      # (B, 1, H, dk)
    v: jnp.ndarray,      # (B, 1, H, dv)
    log_a: jnp.ndarray,  # (B, 1, H)
    state: GLAState,
    *,
    normalize: bool = True,
) -> Tuple[jnp.ndarray, GLAState]:
    """One recurrent step (serving): O(dk·dv) per head, no history."""
    a = jnp.exp(log_a[:, 0].astype(jnp.float32))[..., None]  # (B, H, 1)
    q1 = q[:, 0].astype(jnp.float32)
    k1 = k[:, 0].astype(jnp.float32)
    v1 = v[:, 0].astype(jnp.float32)
    S_new = a[..., None] * state.S + k1[..., None] * v1[..., None, :]
    n_new = a * state.n + k1
    y = jnp.einsum("bhk,bhkv->bhv", q1, S_new)
    if normalize:
        denom = jnp.abs(jnp.einsum("bhk,bhk->bh", q1, n_new))
        y = y / jnp.maximum(denom, 1.0)[..., None]
    return y[:, None].astype(v.dtype), GLAState(S_new, n_new)


# ---------------------------------------------------------------------------
# sLSTM — strictly sequential scalar-memory cell with recurrent mixing
# ---------------------------------------------------------------------------


class SLSTMState(NamedTuple):
    c: jnp.ndarray  # (B, D)
    n: jnp.ndarray  # (B, D)
    h: jnp.ndarray  # (B, D)
    m: jnp.ndarray  # (B, D) — exponential-gate stabilizer


def slstm_scan(
    gates_x: jnp.ndarray,  # (B, S, 4, D) — pre-activations of i, f, z, o from W x
    r_weights: jnp.ndarray,  # (H, 4, dh, dh) block-diagonal recurrent weights
    n_heads: int,
    *,
    init_state: Optional[SLSTMState] = None,
    step_mask: Optional[jnp.ndarray] = None,  # (B, S) bool; False = freeze state
) -> Tuple[jnp.ndarray, SLSTMState]:
    """xLSTM sLSTM cell (exponential gating, max stabilizer, per-head
    block-diagonal recurrence). Sequential by construction — lax.scan over
    time; the HLO stays one cell body regardless of sequence length.

    ``step_mask`` marks which timesteps are real: masked-off steps carry the
    previous state through unchanged (exponential gating has no neutral
    input, so right-padded prefill batches need an explicit state select).
    """
    B, S, _, D = gates_x.shape
    dh = D // n_heads

    def heads(x):  # (B, D) -> (B, H, dh)
        return x.reshape(B, n_heads, dh)

    if init_state is None:
        z = jnp.zeros((B, D), jnp.float32)
        init_state = SLSTMState(z, z, z, jnp.full((B, D), -1e30, jnp.float32))

    def body(state, inp):
        g_t, mask_t = inp  # (B, 4, D), (B,)
        # recurrent contribution: R h_{t-1}, block-diagonal per head
        rh = jnp.einsum("hgij,bhj->bghi", r_weights.astype(jnp.float32), heads(state.h))
        pre = g_t.astype(jnp.float32) + rh.reshape(B, 4, D)
        i_t, f_t, z_t, o_t = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
        # stabilized exponential gating (xLSTM Eq. 15-17)
        log_f = jax.nn.log_sigmoid(f_t)
        m_new = jnp.maximum(log_f + state.m, i_t)
        i_p = jnp.exp(i_t - m_new)
        f_p = jnp.exp(log_f + state.m - m_new)
        c_new = f_p * state.c + i_p * jnp.tanh(z_t)
        n_new = f_p * state.n + i_p
        h_tilde = c_new / jnp.maximum(jnp.abs(n_new), 1.0)
        h_new = jax.nn.sigmoid(o_t) * h_tilde
        new_state = SLSTMState(c_new, n_new, h_new, m_new)
        keep = mask_t[:, None]
        new_state = SLSTMState(
            *(jnp.where(keep, n, o) for n, o in zip(new_state, state))
        )
        return new_state, h_new

    gates_t = gates_x.swapaxes(0, 1)  # (S, B, 4, D)
    if step_mask is None:
        mask_t = jnp.ones((S, B), bool)
    else:
        mask_t = step_mask.swapaxes(0, 1).astype(bool)
    final, hs = jax.lax.scan(body, init_state, (gates_t, mask_t))
    return hs.swapaxes(0, 1).astype(gates_x.dtype), final
