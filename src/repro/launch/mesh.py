"""Production meshes. Functions only — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import)."""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips as (data=16, model=16). Multi-pod: 2 pods = 512
    chips as (pod=2, data=16, model=16); the pod axis carries pure data
    parallelism over DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests use small host-device meshes, e.g. (2, 4))."""
    return jax.make_mesh(tuple(shape), tuple(axes))
