import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST precede any jax import: jax locks the device count
# on first init. 512 host devices back the 2x16x16 multi-pod mesh.
if os.environ.get("REPRO_DRYRUN_DEVICES"):  # test hook (small meshes)
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_DRYRUN_DEVICES"]
    )

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves the distribution config is coherent without real
hardware: jit with explicit shardings must lower, SPMD-partition, and compile
for the 16x16 single-pod mesh and the 2x16x16 multi-pod mesh; we record
memory_analysis (fits / doesn't), cost_analysis (FLOPs & bytes for
§Roofline), and the collective schedule parsed from the optimized HLO.

Usage:
    python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --out results/dryrun.json
"""

import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, cell_is_runnable, get_config
from repro.core.optimizers import make_optimizer
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import decode_cache_len, input_specs
from repro.models import ModelConfig, decode_step, init_model, loss_fn, prefill
from repro.roofline.analysis import (
    collective_bytes_from_hlo,
    cost_analysis_dict,
    model_flops,
    roofline_terms,
)
from repro.sharding import batch_shardings, cache_shardings, param_shardings, replicated
from repro.train.train_loop import (
    TrainState,
    build_train_step,
    make_train_state,
    train_state_shardings,
)


def _param_shapes_and_axes(cfg: ModelConfig):
    params_s, axes = jax.eval_shape(lambda: init_model(jax.random.PRNGKey(0), cfg))
    # eval_shape maps the axes tuples through too — rebuild them concretely
    _, axes = init_model_axes(cfg)
    return params_s, axes


def init_model_axes(cfg: ModelConfig):
    """Axes tree without allocating params (init under eval_shape, axes via
    a real tiny trace of the same structure)."""
    # axes are pure python metadata — build by running init at shape level
    closure = {}

    def capture():
        p, a = init_model(jax.random.PRNGKey(0), cfg)
        closure["axes"] = a
        return p

    params_s = jax.eval_shape(capture)
    return params_s, closure["axes"]


def lower_cell(arch: str, shape_name: str, mesh_kind: str, opt_name: str = "adamw4bit",
               accum_steps: int = 8):
    """Lower + compile one cell; returns the result record.

    Train cells default to 8-way gradient accumulation: at global batch 256
    x 4k tokens the per-layer remat residuals alone are ~16 GB/device on the
    single-pod mesh — microbatching is the standard way production runs fit
    v5e HBM (recorded in EXPERIMENTS.md §Dry-run)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if os.environ.get("REPRO_ACCUM"):
        accum_steps = int(os.environ["REPRO_ACCUM"])
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size

    params_s, axes = init_model_axes(cfg)
    if shape.kind != "train":
        # serving uses bf16 weights (no fp32 masters outside training)
        params_s = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16), params_s
        )
    specs = input_specs(cfg, shape)

    t0 = time.time()
    if shape.kind == "train":
        opt = make_optimizer(opt_name, 1e-4)
        # Always thread an SR key: proves the stochastic-rounding production
        # path (adamw4bit+SR / production4bit) lowers and SPMD-partitions;
        # deterministic optimizers simply ignore it.
        sr_key = jax.random.PRNGKey(0)
        state_s = jax.eval_shape(
            lambda: make_train_state_from_shapes(params_s, opt, key=sr_key)
        )
        from repro.comms import CommsConfig
        # REPRO_GRAD_COMM selects the gradient-collective wire format
        # (fp32/bf16/int8/int4).
        comm_mode = os.environ.get("REPRO_GRAD_COMM", "fp32")
        step_fn = build_train_step(cfg, opt, mesh, axes, zero=True,
                                   accum_steps=accum_steps,
                                   comms=CommsConfig.parse(comm_mode))
        state_sh = train_state_shardings(state_s, axes, mesh, zero=True)
        batch_sh = batch_shardings(specs, mesh)
        with mesh:
            lowered = jax.jit(
                step_fn,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,),
            ).lower(state_s, specs)
            compiled = lowered.compile()
    elif shape.kind == "prefill":
        p_sh = param_shardings(params_s, axes, mesh)
        batch_sh = batch_shardings(specs, mesh)

        def prefill_fn(params, batch):
            return prefill(params, cfg, batch)

        with mesh:
            lowered = jax.jit(
                prefill_fn, in_shardings=(p_sh, batch_sh)
            ).lower(params_s, specs)
            compiled = lowered.compile()
    else:  # decode
        p_sh = param_shardings(params_s, axes, mesh)
        cache_sh = cache_shardings(specs["caches"], mesh)
        tok_sh = batch_shardings(
            {"tokens": specs["tokens"], "pos": specs["pos"]}, mesh
        )
        enc_specs = specs.get("enc_out")

        if enc_specs is not None:
            enc_sh = batch_shardings({"e": enc_specs}, mesh)["e"]

            def decode_fn(params, caches, tokens, pos, enc_out):
                return decode_step(params, cfg, caches, tokens, pos, enc_out=enc_out)

            in_sh = (p_sh, cache_sh, tok_sh["tokens"], tok_sh["pos"], enc_sh)
            args = (params_s, specs["caches"], specs["tokens"], specs["pos"], enc_specs)
        else:

            def decode_fn(params, caches, tokens, pos):
                return decode_step(params, cfg, caches, tokens, pos)

            in_sh = (p_sh, cache_sh, tok_sh["tokens"], tok_sh["pos"])
            args = (params_s, specs["caches"], specs["tokens"], specs["pos"])

        with mesh:
            lowered = jax.jit(
                decode_fn,
                in_shardings=in_sh,
                out_shardings=(None, cache_sh),
                donate_argnums=(1,),
            ).lower(*args)
            compiled = lowered.compile()

    compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    print(mem)  # proves it fits
    cost = cost_analysis_dict(compiled)
    print({k: cost[k] for k in ("flops", "bytes accessed") if k in cost})
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)

    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mflops = model_flops(cfg, params_s, axes, shape.kind, tokens)
    terms = roofline_terms(cost, coll["total"], n_chips, mflops)

    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "n_chips": n_chips,
        "accum_steps": accum_steps if shape.kind == "train" else None,
        "status": "ok",
        "compile_s": round(compile_s, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "cost": {k: cost.get(k) for k in ("flops", "bytes accessed")},
        "collectives": coll,
        "roofline": terms.as_dict(),
    }
    return record


def make_train_state_from_shapes(params_s, opt, key=None):
    params = jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), params_s
    )
    return make_train_state(params, opt, key=key)


def run_all(out_path: str, meshes=("single", "multi"), archs=None, shapes=None):
    results = []
    if os.path.exists(out_path):
        results = json.load(open(out_path))
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results}

    for arch in archs or ARCHS:
        for shape_name in shapes or SHAPES:
            runnable, reason = cell_is_runnable(arch, shape_name)
            for mesh_kind in meshes:
                key = (arch, shape_name, mesh_kind)
                if key in done:
                    continue
                if not runnable:
                    results.append({
                        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
                        "status": "skipped", "reason": reason,
                    })
                    continue
                print(f"=== {arch} x {shape_name} x {mesh_kind} ===", flush=True)
                try:
                    rec = lower_cell(arch, shape_name, mesh_kind)
                except Exception as e:  # record the failure, keep going
                    rec = {
                        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
                        "status": "error", "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-2000:],
                    }
                    print(rec["error"], flush=True)
                results.append(rec)
                os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
                json.dump(results, open(out_path, "w"), indent=1)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--opt", default="adamw4bit",
                    help="optimizer for train cells (e.g. production4bit)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun.json")
    args = ap.parse_args()

    if args.all:
        run_all(args.out)
        return

    rec = lower_cell(args.arch, args.shape, args.mesh, opt_name=args.opt)
    print(json.dumps(rec, indent=1, default=str))


if __name__ == "__main__":
    main()
