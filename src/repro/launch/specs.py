"""input_specs(): ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, and never allocates — the dry-run lowers
against these. Modality-stub archs get precomputed embeddings (qwen2-vl
patches, whisper audio frames) per the assignment.

Enc-dec shape convention: a shape's seq_len splits evenly into encoder
frames and decoder tokens (whisper train_4k = 2048 frames + 2048 tokens).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ShapeSpec
from repro.models import ModelConfig, init_serve_cache

__all__ = ["input_specs", "serve_cache_specs", "decode_cache_len"]

SDS = jax.ShapeDtypeStruct


def _train_like(cfg: ModelConfig, B: int, S: int, with_labels: bool) -> Dict[str, Any]:
    batch: Dict[str, Any] = {}
    if cfg.family == "encdec":
        Se = Sd = S // 2
        batch["frames"] = SDS((B, Se, cfg.d_model), jnp.bfloat16)
        batch["tokens"] = SDS((B, Sd), jnp.int32)
        if with_labels:
            batch["labels"] = SDS((B, Sd), jnp.int32)
        return batch
    if cfg.input_mode == "embeds":
        batch["embeds"] = SDS((B, S, cfg.d_model), jnp.bfloat16)
    else:
        batch["tokens"] = SDS((B, S), jnp.int32)
    if cfg.rope_variant == "mrope":
        batch["positions"] = SDS((3, B, S), jnp.int32)
    if with_labels:
        batch["labels"] = SDS((B, S), jnp.int32)
    return batch


def decode_cache_len(cfg: ModelConfig, shape: ShapeSpec) -> int:
    """Cache capacity for a decode shape. Enc-dec splits seq in half."""
    return shape.seq_len // 2 if cfg.family == "encdec" else shape.seq_len


def serve_cache_specs(cfg: ModelConfig, B: int, s_max: int):
    """ShapeDtypeStructs of the decode cache tree (no allocation)."""
    return jax.eval_shape(lambda: init_serve_cache(cfg, B, s_max))


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Inputs for the step function the shape lowers:

    * train  -> train_step batch (tokens/embeds/frames + labels)
    * prefill-> prefill batch (no labels)
    * decode -> {tokens (B,), pos (B,), caches, [enc_out]}
    """
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return _train_like(cfg, B, S, with_labels=True)
    if shape.kind == "prefill":
        return _train_like(cfg, B, S, with_labels=False)
    # decode
    s_max = decode_cache_len(cfg, shape)
    out: Dict[str, Any] = {
        "tokens": SDS((B,), jnp.int32),
        "pos": SDS((B,), jnp.int32),
        "caches": serve_cache_specs(cfg, B, s_max),
    }
    if cfg.family == "encdec":
        out["enc_out"] = SDS((B, s_max, cfg.d_model), jnp.bfloat16)
    return out
