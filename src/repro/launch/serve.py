"""Serving CLI: continuous-batching decode on a reduced config.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --requests 8
"""

import argparse

import jax

from repro.configs import ARCHS, reduced_config
from repro.models import init_model
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCHS))
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=2)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    if cfg.family == "encdec" or cfg.input_mode == "embeds":
        raise SystemExit(f"{args.arch}: token-decoder archs only in this CLI")
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_batch=args.max_batch, s_max=256)
    for i in range(args.requests):
        eng.submit(Request(rid=i, prompt=[1 + i, 2 + i],
                           max_new_tokens=args.max_new_tokens))
    eng.run()
    for i in range(args.requests):
        pass
    print(f"served {args.requests} requests, "
          f"{args.max_new_tokens} tokens each (greedy, continuous batching)")


if __name__ == "__main__":
    main()
