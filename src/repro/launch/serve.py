"""Serving CLI: throughput engine on a reduced config.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --requests 8 \
        --weights q4 --temperature 0.8 --top-k 40
"""

import argparse
import time

import jax

from repro.configs import ARCHS, reduced_config
from repro.models import init_model
from repro.serve import Request, ServeEngine, format_weight_table


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCHS))
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=2)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--weights", default="bf16", choices=("bf16", "q4"),
                    help="serving weight format (q4 = 4-bit block-quantized)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; > 0 samples on device")
    ap.add_argument("--top-k", type=int, default=0, help="0 = full vocab")
    ap.add_argument("--seed", type=int, default=0, help="sampling stream seed")
    ap.add_argument("--drain-every", type=int, default=8,
                    help="decode steps per host sync")
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    if cfg.family == "encdec" or cfg.input_mode == "embeds":
        raise SystemExit(f"{args.arch}: token-decoder archs only in this CLI")
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(
        cfg, params, max_batch=args.max_batch, s_max=256,
        weights=args.weights, drain_every=args.drain_every, seed=args.seed,
    )
    reqs = [
        Request(rid=i, prompt=[1 + i, 2 + i],
                max_new_tokens=args.max_new_tokens,
                temperature=args.temperature, top_k=args.top_k)
        for i in range(args.requests)
    ]
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    eng.run()
    wall = time.perf_counter() - t0

    total_tokens = sum(len(r.output) for r in reqs)
    mode = "greedy" if args.temperature <= 0 else (
        f"T={args.temperature} top_k={args.top_k}"
    )
    print(format_weight_table([eng.weight_bytes()], title="serving weights"))
    print(
        f"served {args.requests} requests / {total_tokens} tokens in "
        f"{wall:.2f}s ({mode}, drain_every={args.drain_every}, "
        f"{total_tokens / wall / args.max_batch:.1f} tok/s/slot incl. compile)"
    )


if __name__ == "__main__":
    main()
