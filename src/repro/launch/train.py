"""Training CLI: --arch <id> [--reduced] --steps N.

Full configs are intended for the TPU meshes (use dryrun.py to validate the
distribution); --reduced runs the same code path at CPU scale end-to-end
(data pipeline -> sharded step -> 4-bit optimizer -> checkpoints).

    PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --reduced --steps 30

Production-path flags:
  --optimizer production4bit   fp32 embeddings/norms + 4-bit SR body
  --sr-seed N                  thread a stochastic-rounding PRNG key through
                               the train step (unbiased quantization, Alg. 1)
  --grad-comm MODE             gradient-collective wire format
                               (fp32|bf16|int8|int4): int8/int4 move
                               block-quantized codes+scales through the
                               cross-device reduction instead of fp32, with
                               SR keyed off the --sr-seed stream (unbiased
                               transport, bit-reproducible across resume);
                               replaces the removed grad_dtype plumbing
  --mesh DxM                   run on a (data=D, model=M) host-device mesh via
                               jit_train_step with explicit shardings
  --ckpt-dir PATH              resume is elastic: the restore target is built
                               abstractly (jax.eval_shape over
                               make_train_state — no throwaway concrete init,
                               so restore never doubles device memory) and
                               re-sharded onto the current mesh.  Saves use
                               the sharded v2 format (repro.io): per-host
                               shard files written on a background thread,
                               COMMIT-marker atomicity.
  --keep-last N / --keep-every K
                               retention: keep the newest N complete steps
                               plus every K-th step; superseded dirs are
                               GC'd after each successful commit.
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.comms import GRAD_COMM_MODES, CommsConfig, wire_report
from repro.configs import ARCHS, get_config, reduced_config
from repro.core.optimizers import (
    linear_warmup_linear_decay,
    make_optimizer,
    optimizer_names,
    state_nbytes,
)
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_mesh
from repro.models import init_model
from repro.train.checkpoint import CheckpointManager
from repro.train.train_loop import (
    build_train_step,
    jit_train_step,
    make_train_state,
    train_state_shardings,
)


def _parse_value(v: str):
    """--opt-arg value: bool words, then any Python literal (1e-8, -0.5, 3),
    falling back to the raw string."""
    import ast

    low = v.lower()
    if low in ("true", "false"):
        return low == "true"
    try:
        return ast.literal_eval(v)
    except (ValueError, SyntaxError):
        return v


def _uses_stochastic_rounding(opt_state) -> bool:
    from repro.core.quantizer import QuantizedTensor

    return any(
        l.config.stochastic_rounding
        for l in jax.tree_util.tree_leaves(
            opt_state, is_leaf=lambda x: isinstance(x, QuantizedTensor)
        )
        if isinstance(l, QuantizedTensor)
    )


def abstract_train_state(cfg, optimizer, key=None):
    """(abstract TrainState, axes) without allocating a single param.

    The whole init (model params -> optimizer state -> TrainState) runs under
    ``jax.eval_shape``, so every leaf is a ShapeDtypeStruct.  This is the
    restore target: the old ``jax.eval_shape(lambda: state)`` idiom required
    a *concrete* state to already exist, which meant a resuming process
    allocated the full model twice (fresh init + restored copy) before the
    first could be dropped.
    """
    captured = {}

    def build():
        params, axes = init_model(jax.random.PRNGKey(0), cfg)
        captured["axes"] = axes
        return make_train_state(params, optimizer, key=key)

    state_s = jax.eval_shape(build)
    return state_s, captured["axes"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCHS))
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-scale config of the same family")
    ap.add_argument("--optimizer", default="adamw4bit",
                    choices=list(optimizer_names()))
    ap.add_argument("--opt-arg", action="append", default=[],
                    metavar="K=V",
                    help="optimizer override, e.g. --opt-arg use_kernel=true "
                         "(validated by make_optimizer)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--sr-seed", type=int, default=None,
                    help="seed for the stochastic-rounding PRNG key stream "
                         "(required for unbiased SR; omit for deterministic "
                         "round-to-nearest)")
    ap.add_argument("--grad-comm", default="fp32",
                    choices=list(GRAD_COMM_MODES),
                    help="gradient-collective wire format; int8/int4 "
                         "block-quantize the cross-device reduction "
                         "(docs/comms.md)")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="host-device mesh, e.g. 2x4 (data=2, model=4); "
                         "needs D*M local devices")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--keep-last", type=int, default=3,
                    help="retention: keep the newest N complete checkpoints "
                         "(superseded step dirs are GC'd after each commit)")
    ap.add_argument("--keep-every", type=int, default=None,
                    help="retention: additionally keep every K-th step as a "
                         "periodic archival anchor")
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if not args.reduced:
        print("note: full config on CPU — expect long compiles; "
              "use --reduced or launch/dryrun.py for the mesh path")
    if cfg.input_mode == "embeds" or cfg.family == "encdec":
        raise SystemExit(
            f"{args.arch}: modality-stub arch — use examples/ or the dry-run"
        )

    for kv in args.opt_arg:
        if "=" not in kv:
            raise SystemExit(
                f"--opt-arg {kv!r}: expected K=V (e.g. use_kernel=true)"
            )
    overrides = {k: _parse_value(v) for k, _, v in
                 (kv.partition("=") for kv in args.opt_arg)}
    opt = make_optimizer(
        args.optimizer,
        linear_warmup_linear_decay(args.lr, max(1, args.steps // 10), args.steps),
        **overrides,
    )
    sr_key = (
        jax.random.PRNGKey(args.sr_seed) if args.sr_seed is not None else None
    )

    mesh = None
    if args.mesh:
        d, _, m = args.mesh.partition("x")
        mesh = make_mesh((int(d), int(m)), ("data", "model"))

    mgr = (
        CheckpointManager(
            args.ckpt_dir, keep_last=args.keep_last, keep_every=args.keep_every
        )
        if args.ckpt_dir
        else None
    )
    # newest COMMIT-complete step: a save killed mid-write is skipped
    start = (mgr.latest_step() or 0) if mgr else 0

    if start:
        # Elastic resume: abstract target + shardings for the current mesh.
        target, axes = abstract_train_state(cfg, opt, key=sr_key)
        shardings = (
            train_state_shardings(target, axes, mesh) if mesh is not None else None
        )
        state, _ = mgr.restore(target, shardings=shardings)
        print(f"resumed from step {start}")
    else:
        params, axes = init_model(jax.random.PRNGKey(0), cfg)
        state = make_train_state(params, opt, key=sr_key)
    print(f"arch={cfg.name} optimizer={opt.name} "
          f"state_bytes={state_nbytes(state.opt_state):,}")

    comms = CommsConfig.parse(args.grad_comm)
    rep = wire_report(state.params, comms)
    print(f"grad-comm={comms.name} collective_bytes/step="
          f"{rep['total_wire_bytes']:,} "
          f"({rep['ratio_vs_fp32']:.2f}x fewer than fp32, "
          f"{rep['quantized_leaves']}/{rep['n_leaves']} leaves quantized)")

    if sr_key is None and _uses_stochastic_rounding(state.opt_state):
        print("warning: optimizer is configured for stochastic rounding but "
              "no --sr-seed was given — quantization falls back to biased "
              "round-to-nearest")
    if sr_key is None and comms.quantized and comms.stochastic_rounding:
        print("warning: --grad-comm " + comms.mode + " transports gradients "
              "with stochastic rounding but no --sr-seed was given — "
              "transport falls back to biased round-to-nearest")

    data = SyntheticLM(DataConfig(cfg.vocab_size, args.seq, args.batch))
    if mesh is not None:
        sample = {k: jnp.asarray(v) for k, v in data.batch_at(start).items()}
        step_fn = jit_train_step(
            build_train_step(cfg, opt, mesh, axes, zero=True, comms=comms),
            state, sample, axes, mesh,
        )
    else:
        step_fn = jax.jit(
            build_train_step(cfg, opt, comms=comms), donate_argnums=(0,)
        )

    for t in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(t).items()}
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        if mgr and (t + 1) % args.ckpt_every == 0:
            mgr.save(t + 1, state)
        if t % 5 == 0:
            print(f"step {t:4d} loss {float(metrics['loss']):.4f} "
                  f"({(time.perf_counter()-t0)*1e3:.0f} ms)")
    if mgr:
        mgr.wait()


if __name__ == "__main__":
    main()
