"""Training CLI: --arch <id> [--reduced] --steps N.

Full configs are intended for the TPU meshes (use dryrun.py to validate the
distribution); --reduced runs the same code path at CPU scale end-to-end
(data pipeline -> sharded step -> 4-bit optimizer -> checkpoints).

    PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --reduced --steps 30
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, reduced_config
from repro.core.optimizers import (
    linear_warmup_linear_decay,
    make_optimizer,
    optimizer_names,
    state_nbytes,
)
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import init_model
from repro.train.checkpoint import CheckpointManager, latest_step
from repro.train.train_loop import build_train_step, make_train_state


def _parse_value(v: str):
    """--opt-arg value: bool words, then any Python literal (1e-8, -0.5, 3),
    falling back to the raw string."""
    import ast

    low = v.lower()
    if low in ("true", "false"):
        return low == "true"
    try:
        return ast.literal_eval(v)
    except (ValueError, SyntaxError):
        return v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCHS))
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-scale config of the same family")
    ap.add_argument("--optimizer", default="adamw4bit",
                    choices=list(optimizer_names()))
    ap.add_argument("--opt-arg", action="append", default=[],
                    metavar="K=V",
                    help="optimizer override, e.g. --opt-arg use_kernel=true "
                         "(validated by make_optimizer)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if not args.reduced:
        print("note: full config on CPU — expect long compiles; "
              "use --reduced or launch/dryrun.py for the mesh path")
    if cfg.input_mode == "embeds" or cfg.family == "encdec":
        raise SystemExit(
            f"{args.arch}: modality-stub arch — use examples/ or the dry-run"
        )

    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    overrides = {k: _parse_value(v) for k, _, v in
                 (kv.partition("=") for kv in args.opt_arg)}
    opt = make_optimizer(
        args.optimizer,
        linear_warmup_linear_decay(args.lr, max(1, args.steps // 10), args.steps),
        **overrides,
    )
    state = make_train_state(params, opt)
    print(f"arch={cfg.name} optimizer={opt.name} "
          f"state_bytes={state_nbytes(state.opt_state):,}")

    step_fn = jax.jit(build_train_step(cfg, opt), donate_argnums=(0,))
    data = SyntheticLM(DataConfig(cfg.vocab_size, args.seq, args.batch))
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    start = (latest_step(args.ckpt_dir) or 0) if args.ckpt_dir else 0
    if start:
        state, _ = mgr.restore(jax.eval_shape(lambda: state))
        print(f"resumed from step {start}")

    for t in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(t).items()}
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        if mgr and (t + 1) % args.ckpt_every == 0:
            mgr.save(t + 1, state)
        if t % 5 == 0:
            print(f"step {t:4d} loss {float(metrics['loss']):.4f} "
                  f"({(time.perf_counter()-t0)*1e3:.0f} ms)")
    if mgr:
        mgr.wait()


if __name__ == "__main__":
    main()
