"""Fault tolerance: straggler detection, failure handling, elastic rescale.

Policy layer designed for 1000+ nodes; the mechanisms are real and unit
tested in-process, with the multi-host transport (heartbeats over the
coordination service) abstracted behind ``HostMonitor`` so a single-process
simulation exercises the same code paths the launcher would use.

Components:
  * StragglerDetector — rolling-median step times; hosts slower than
    k×median for m consecutive steps are flagged.
  * HostMonitor — heartbeat registry; missed deadlines mark a host dead.
  * ElasticPlan — given surviving hosts, recompute the data sharding
    (hosts re-derive their slice from (step, host_index, num_hosts) — the
    pipeline is stateless) and decide restore-from-checkpoint.
  * run_with_recovery — drives a train loop with simulated failures:
    on failure, restore latest checkpoint, re-plan, continue.
  * checkpoint_hooks — wires run_with_recovery's (save, restore_latest)
    callbacks onto a sharded ``repro.io.CheckpointManager``: async saves,
    and restore that falls back past incomplete (uncommitted) save dirs.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "StragglerDetector",
    "HostMonitor",
    "ElasticPlan",
    "run_with_recovery",
    "checkpoint_hooks",
]


class StragglerDetector:
    """Flags hosts whose step time exceeds ``threshold`` x rolling median for
    ``patience`` consecutive steps."""

    def __init__(self, threshold: float = 1.5, window: int = 16, patience: int = 3):
        self.threshold = threshold
        self.window = window
        self.patience = patience
        self._times: Dict[int, collections.deque] = {}
        self._strikes: Dict[int, int] = collections.defaultdict(int)

    def record(self, host: int, step_time: float):
        self._times.setdefault(host, collections.deque(maxlen=self.window)).append(
            step_time
        )

    def medians(self) -> Dict[int, float]:
        return {h: float(np.median(t)) for h, t in self._times.items() if t}

    def stragglers(self) -> List[int]:
        meds = self.medians()
        if len(meds) < 2:
            return []
        global_median = float(np.median(list(meds.values())))
        out = []
        for h, m in meds.items():
            if m > self.threshold * global_median:
                self._strikes[h] += 1
            else:
                self._strikes[h] = 0
            if self._strikes[h] >= self.patience:
                out.append(h)
        return out


class HostMonitor:
    """Heartbeat registry. In production the heartbeats ride the coordination
    service; here they are injected (simulation) through ``beat``."""

    def __init__(self, hosts: Sequence[int], deadline_s: float = 60.0, clock=time.monotonic):
        self.deadline_s = deadline_s
        self.clock = clock
        self.last_beat = {h: clock() for h in hosts}

    def beat(self, host: int, at: Optional[float] = None):
        self.last_beat[host] = self.clock() if at is None else at

    def dead_hosts(self) -> List[int]:
        now = self.clock()
        return [h for h, t in self.last_beat.items() if now - t > self.deadline_s]

    def alive(self) -> List[int]:
        dead = set(self.dead_hosts())
        return [h for h in self.last_beat if h not in dead]


@dataclasses.dataclass
class ElasticPlan:
    """Resharding decision after membership change."""

    hosts: List[int]
    restore_step: Optional[int]

    @property
    def num_hosts(self) -> int:
        return len(self.hosts)

    def host_index(self, host: int) -> int:
        return self.hosts.index(host)


def plan_elastic(
    alive_hosts: Sequence[int],
    latest_checkpoint: Optional[int],
    min_hosts: int = 1,
) -> ElasticPlan:
    hosts = sorted(alive_hosts)
    if len(hosts) < min_hosts:
        raise RuntimeError(
            f"only {len(hosts)} hosts alive, below minimum {min_hosts}"
        )
    return ElasticPlan(hosts=hosts, restore_step=latest_checkpoint)


def checkpoint_hooks(
    manager,
    get_state: Callable[[], object],
    set_state: Callable[[object], None],
    make_target: Callable[[], object],
    make_shardings: Optional[Callable[[], object]] = None,
) -> Tuple[Callable[[int], None], Callable[[], int]]:
    """(save, restore_latest) callbacks for ``run_with_recovery`` backed by a
    sharded ``repro.io.CheckpointManager``.

    ``save(step)`` snapshots ``get_state()`` and returns as soon as the
    device->host copy is done (serialization + COMMIT run in the background).
    ``restore_latest()`` restores the newest *complete* step — a save that
    was killed mid-shard-write (no COMMIT marker, truncated shard file) is
    skipped, so recovery lands on the last committed state — hands it to
    ``set_state``, and returns the step to resume from (0 when no complete
    checkpoint exists).  ``make_target`` builds the abstract restore target;
    ``make_shardings`` (optional) supplies shardings for the current mesh so
    an elastic restart re-shards on the way in.
    """

    def save(step: int) -> None:
        manager.save(step, get_state())

    def restore_latest() -> int:
        # manager.latest_step drains in-flight saves itself, so the step it
        # reports cannot be superseded (and GC'd) by a pending async commit.
        try:
            step = manager.latest_step()
        except Exception as e:
            # A background save that failed (ENOSPC, disk fault) must not
            # abort recovery — falling back to the last COMMIT-complete step
            # is this function's whole contract.  The writer queue is
            # drained by the time wait() re-raises, so a direct scan of the
            # directory cannot race an in-flight commit.
            import warnings

            from repro.io import format as _ckfmt

            warnings.warn(
                f"discarding failed async checkpoint save during recovery: {e!r}"
            )
            step = _ckfmt.latest_step(manager.directory)
        if step is None:
            return 0
        shardings = make_shardings() if make_shardings is not None else None
        state, _ = manager.restore(make_target(), step=step, shardings=shardings)
        set_state(state)
        return step

    return save, restore_latest


def run_with_recovery(
    steps: int,
    train_one: Callable[[int], float],
    save: Callable[[int], None],
    restore_latest: Callable[[], int],
    checkpoint_every: int = 10,
    failure_injector: Optional[Callable[[int], bool]] = None,
    max_restarts: int = 10,
):
    """Drive a loop with checkpoint/restart semantics. ``train_one(step)``
    returns the loss; ``failure_injector(step)`` returning True simulates a
    node failure at that step. Returns (losses, restarts, steps_replayed)."""
    losses: List[float] = []
    restarts = 0
    replayed = 0
    step = 0
    while step < steps:
        try:
            if failure_injector is not None and failure_injector(step):
                raise RuntimeError(f"injected node failure at step {step}")
            loss = train_one(step)
            losses.append(loss)
            if (step + 1) % checkpoint_every == 0:
                save(step + 1)
            step += 1
        except RuntimeError:
            restarts += 1
            if restarts > max_restarts:
                raise
            resumed = restore_latest()
            replayed += step - resumed
            step = resumed
    return losses, restarts, replayed
