"""Distributed training step builder.

Composes the model loss, gradient accumulation, ZeRO sharding constraints,
and the compressed optimizer (the paper's technique) into one pjit-able
``train_step(state, batch) -> (state, metrics)``.

Distribution model (DESIGN.md §5):
  * batch over pod×data; TP per the rules engine,
  * gradients constrained to the ZeRO layout (forces reduce-scatter),
  * optimizer states (packed 4-bit codes + scales) sharded over pod×data —
    8x less state traffic than fp32 states, the paper's communication claim,
  * updated params emitted with the TP-only layout (all-gather at the end).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.comms import CommsConfig, grad_comm_key, reduce_grads
from repro.core.optimizers.base import Optimizer
from repro.core.optimizers.transform import GradientTransformation, as_optimizer
from repro.models import ModelConfig, loss_fn
from repro.sharding import (
    batch_shardings,
    opt_state_shardings,
    param_shardings,
    replicated,
)

__all__ = ["TrainState", "build_train_step", "make_train_state", "train_state_shardings"]


@jax.tree_util.register_pytree_with_keys_class
class TrainState:
    """params (fp32 masters) + compressed optimizer state + step counter +
    optional stochastic-rounding base PRNG key.

    ``key`` is the *base* key: every step re-derives its SR key as
    ``fold_in(key, step)``, so the key stream is a pure function of
    (base key, step counter) and a checkpoint restore reproduces the exact
    same quantization noise as the uninterrupted run — bit-exact resume even
    under stochastic rounding.  ``key=None`` (the default) trains without SR
    randomness (round-to-nearest quantization everywhere).
    """

    def __init__(self, params, opt_state, step, key=None):
        self.params = params
        self.opt_state = opt_state
        self.step = step
        self.key = key

    def tree_flatten_with_keys(self):
        k = jax.tree_util.GetAttrKey
        return (
            (k("params"), self.params),
            (k("opt_state"), self.opt_state),
            (k("step"), self.step),
            (k("key"), self.key),
        ), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def _coerce_optimizer(optimizer) -> Optimizer:
    """Accept either the Optimizer facade or a bare transformation chain."""
    if isinstance(optimizer, GradientTransformation):
        return as_optimizer(optimizer)
    return optimizer


def make_train_state(params, optimizer, key: Optional[jax.Array] = None) -> TrainState:
    """``key`` seeds stochastic rounding (see ``TrainState``); pass one for
    ``adamw4bit(stochastic_rounding=True)`` / ``sgdm4bit`` / ``production4bit``.
    It is harmless for deterministic optimizers (they ignore it)."""
    optimizer = _coerce_optimizer(optimizer)
    return TrainState(params, optimizer.init(params), jnp.zeros((), jnp.int32), key)


def train_state_shardings(state, axes, mesh: Mesh, zero: bool = True):
    return TrainState(
        params=param_shardings(state.params, axes, mesh, zero=zero),
        opt_state=opt_state_shardings(state.opt_state, state.params, axes, mesh, zero=zero),
        step=replicated(mesh),
        key=None if state.key is None else replicated(mesh),
    )


def build_train_step(
    cfg: ModelConfig,
    optimizer,  # Optimizer facade or a bare GradientTransformation chain
    mesh: Optional[Mesh] = None,
    axes=None,
    *,
    zero: bool = True,
    accum_steps: int = 1,
    comms: Optional[CommsConfig] = None,
) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    ``accum_steps > 1`` splits the batch leading dim into microbatches and
    accumulates gradients in fp32 (scan over microbatches — peak activation
    memory drops by the accumulation factor).  The microbatch loop itself is
    deterministic (the loss consumes no randomness); stochastic rounding
    happens once, at the post-accumulation optimizer update, keyed by
    ``fold_in(state.key, state.step)`` when ``state.key`` is set.

    ``comms`` selects the gradient-collective wire format (``repro.comms``):
    fp32 (default), bf16 cast, or int8/int4 block-quantized transport with
    SR keyed off the same checkpointed key stream.  It is the only
    wire-format knob (the pre-PR-6 ``grad_dtype=`` spelling is gone).
    """
    optimizer = _coerce_optimizer(optimizer)
    comms = comms if comms is not None else CommsConfig()

    def compute_grads(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch), has_aux=True
        )(params)
        return loss, metrics, grads

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        from repro.sharding.context import sharding_ctx
        import contextlib

        ctx = (
            sharding_ctx(mesh, axes, zero=zero)
            if mesh is not None
            else contextlib.nullcontext()
        )
        with ctx:
            return _train_step_inner(state, batch)

    def _train_step_inner(state: TrainState, batch: Dict[str, jnp.ndarray]):
        params = state.params

        if accum_steps > 1:
            def micro(b_all, i):
                def slice_one(x):
                    if x.ndim == 0:
                        return x
                    # mrope positions are (3, B, S): batch lives on dim 1
                    bdim = 1 if (x.ndim >= 2 and x.shape[0] == 3 and x.shape[1] != 3) else 0
                    size = x.shape[bdim] // accum_steps
                    return jax.lax.dynamic_slice_in_dim(x, i * size, size, axis=bdim)

                return jax.tree_util.tree_map(slice_one, b_all)

            def body(carry, i):
                g_acc, loss_acc = carry
                loss, metrics, grads = compute_grads(params, micro(batch, i))
                g_acc = jax.tree_util.tree_map(jnp.add, g_acc, grads)
                return (g_acc, loss_acc + loss), metrics

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss_sum), metrics_stacked = jax.lax.scan(
                body, (g0, jnp.float32(0)), jnp.arange(accum_steps)
            )
            grads = jax.tree_util.tree_map(lambda g: g / accum_steps, grads)
            loss = loss_sum / accum_steps
            # real per-microbatch metrics, averaged (not total loss relabeled
            # as ce_loss with aux zeroed)
            metrics = jax.tree_util.tree_map(
                lambda x: jnp.mean(x, axis=0), metrics_stacked
            )
        else:
            loss, metrics, grads = compute_grads(params, batch)

        # Gradient collective: constrain to the ZeRO wire layout and apply
        # the configured compression (repro.comms).  Quantized modes derive
        # their transport SR key from the checkpointed (base key, step) pair,
        # domain-separated from the optimizer-state SR stream.
        comms_mesh = mesh if (mesh is not None and zero and axes is not None) else None
        if comms_mesh is not None or comms.compresses:
            ck = (
                grad_comm_key(state.key, state.step)
                if comms.quantized and comms.stochastic_rounding
                else None
            )
            grads = reduce_grads(
                grads, axes if comms_mesh is not None else None,
                comms_mesh, comms, key=ck,
            )

        if state.key is not None:
            # Per-step SR key: a pure function of (base key, step) so a
            # restored run re-derives the identical key stream.  compressed()
            # folds in the leaf index (and splits per moment) downstream.
            step_key = jax.random.fold_in(state.key, state.step)
            new_params, new_opt = optimizer.update(
                grads, state.opt_state, params, key=step_key
            )
        else:
            new_params, new_opt = optimizer.update(grads, state.opt_state, params)
        metrics = dict(metrics)
        metrics["loss"] = loss
        metrics["grad_norm"] = jnp.sqrt(
            sum(
                jnp.sum(g.astype(jnp.float32) ** 2)
                for g in jax.tree_util.tree_leaves(grads)
            )
        )
        return TrainState(new_params, new_opt, state.step + 1, state.key), metrics

    return train_step


def jit_train_step(
    train_step: Callable,
    state: TrainState,
    batch,
    axes,
    mesh: Mesh,
    *,
    zero: bool = True,
    donate: bool = True,
):
    """jit with explicit in/out shardings for the production mesh."""
    state_sh = train_state_shardings(state, axes, mesh, zero=zero)
    batch_sh = batch_shardings(batch, mesh)
    metrics_sh = None  # replicated scalars — let jit infer
    return jax.jit(
        train_step,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, metrics_sh),
        donate_argnums=(0,) if donate else (),
    )
