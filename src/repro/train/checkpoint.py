"""Checkpointing: atomic, async, content-hashed, keep-last-k.

Checkpoints store the *compressed* optimizer state (packed 4-bit codes +
scales) directly — a 4-bit-AdamW checkpoint is ~7x smaller than an fp32-state
checkpoint, which shrinks save/restore time and makes frequent checkpointing
(the first line of fault tolerance) cheap. Restore re-shards onto whatever
mesh is current, so an elastic restart with a different device count works
from the same files.

Layout:
    <dir>/step_000100/
        arrays.npz            # every array leaf, keyed by flattened path
        manifest.json         # structure (treedef repr) + per-leaf key,
                              # shape, dtype, sha256
    <dir>/LATEST              # atomically-updated pointer

The manifest's ``structure`` entry records the full pytree structure —
including the optimizer transform-chain layout (``ChainState`` /
``CompressedState`` / ``PartitionState`` nesting, per-leaf ``QuantConfig``) —
so a restore into a structurally different target fails loudly with both
reprs instead of silently misassigning leaves.  ``migrate_legacy_state``
converts pre-chain ``{"m": ..., "v": ..., "step": ...}`` dict states into the
``ChainState`` layout a transform chain expects.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.optimizers.base import FactoredMoment
from repro.core.quantizer import QuantizedTensor

__all__ = [
    "save_checkpoint",
    "restore_checkpoint",
    "latest_step",
    "CheckpointManager",
    "tree_structure_repr",
    "migrate_legacy_state",
]

_STATE_LEAF = lambda x: isinstance(x, (QuantizedTensor, FactoredMoment))


def tree_structure_repr(tree) -> str:
    """Canonical structure string for manifest validation.

    The treedef repr covers node types, arity, dict keys, and static aux data
    — for optimizer states that includes the transform-chain nesting and each
    ``QuantizedTensor``'s ``QuantConfig``."""
    return str(jax.tree_util.tree_structure(tree))


def _flatten_with_paths(tree) -> List[Tuple[str, np.ndarray]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out.append((key, np.asarray(leaf)))
    return out


def _sha(a: np.ndarray) -> str:
    return hashlib.sha256(a.tobytes()).hexdigest()[:16]


def save_checkpoint(directory: str, step: int, tree: Any, extra: Optional[Dict] = None) -> str:
    """Atomic save: write to tmp dir, fsync, rename, update LATEST."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        leaves = _flatten_with_paths(tree)
        arrays = {f"a{i}": arr for i, (_, arr) in enumerate(leaves)}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "extra": extra or {},
            "structure": tree_structure_repr(tree),
            "leaves": [
                {
                    "key": key,
                    "name": f"a{i}",
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "sha256": _sha(arr),
                }
                for i, (key, arr) in enumerate(leaves)
            ],
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    # atomic LATEST pointer
    latest_tmp = os.path.join(directory, ".LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(str(step))
        f.flush()
        os.fsync(f.fileno())
    os.replace(latest_tmp, os.path.join(directory, "LATEST"))
    return final


def latest_step(directory: str) -> Optional[int]:
    p = os.path.join(directory, "LATEST")
    if not os.path.exists(p):
        return None
    return int(open(p).read().strip())


def restore_checkpoint(
    directory: str,
    target: Any,
    step: Optional[int] = None,
    shardings: Any = None,
    validate: bool = True,
) -> Tuple[Any, Dict]:
    """Restore into the structure of ``target`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings`` (same structure) re-shards every leaf
    onto the current mesh — elastic restart across device counts."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    manifest = json.load(open(os.path.join(d, "manifest.json")))
    npz = np.load(os.path.join(d, "arrays.npz"))

    if validate and "structure" in manifest:
        got = tree_structure_repr(target)
        if got != manifest["structure"]:
            raise ValueError(
                "checkpoint structure mismatch: the restore target's pytree "
                "does not match what was saved.\n"
                f"  saved:  {manifest['structure'][:512]}\n"
                f"  target: {got[:512]}\n"
                "If the checkpoint predates the transform-chain state layout "
                "(dict {'m','v','step'}), restore into the legacy structure "
                "and convert with migrate_legacy_state(state, tx)."
            )

    flat_target = jax.tree_util.tree_flatten_with_path(target)
    paths = [jax.tree_util.keystr(p) for p, _ in flat_target[0]]
    by_key = {m["key"]: m for m in manifest["leaves"]}

    sh_leaves = None
    if shardings is not None:
        sh_leaves = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
        )
        if len(sh_leaves) != len(paths):
            # tree_leaves drops None subtrees, which would silently shift
            # every later leaf onto the wrong sharding — refuse instead.
            raise ValueError(
                f"shardings tree has {len(sh_leaves)} sharding leaves but the "
                f"target has {len(paths)} array leaves; shardings must mirror "
                "the target one sharding per leaf (no None placeholders)"
            )

    out = []
    for i, key in enumerate(paths):
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key}")
        m = by_key[key]
        arr = npz[m["name"]]
        if validate and _sha(arr) != m["sha256"]:
            raise IOError(f"checkpoint corruption at {key} (hash mismatch)")
        if sh_leaves is not None:
            out.append(jax.device_put(arr, sh_leaves[i]))
        else:
            out.append(jnp.asarray(arr))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(target), out
    )
    return tree, manifest["extra"]


def migrate_legacy_state(dict_state: Dict, tx, field_map: Optional[Dict[str, str]] = None):
    """Convert a pre-chain dict optimizer state into ``ChainState`` layout.

    ``dict_state`` is the legacy layout (``{"m": <tree>, "v": <tree>,
    "step": <int32>}`` for AdamW-family; SGDM's momentum lived under ``"m"``),
    with moment leaves raw fp32, ``QuantizedTensor`` or ``FactoredMoment``.
    ``tx`` is the transform chain (or ``Optimizer`` facade) the state should
    feed — it must be built with the same quantization policies the legacy
    run used, which is checked structurally per moment tree.

    Returns ``tx.init``'s state with every moment tree replaced by the legacy
    values and every transform step counter set to the legacy ``"step"``
    (bias correction and schedules continue where the old run stopped).
    ``field_map`` renames legacy keys to chain state fields; the one rename
    the repo's own history needs (SGDM ``"m"`` -> ``"trace"``) is applied
    automatically.
    """
    from repro.core.optimizers.transform import ChainState

    moments = {k: v for k, v in dict_state.items() if k != "step"}
    if not moments:
        raise ValueError("legacy state has no moment trees to migrate")
    step_val = dict_state.get("step")

    # Rebuild a param-shaped tree of zeros from any moment tree: every leaf
    # kind (raw array / QuantizedTensor / FactoredMoment) knows its logical
    # shape, which is all ``init`` needs to re-derive structure + policies.
    template = next(iter(moments.values()))
    params_like = jax.tree_util.tree_map(
        lambda s: jnp.zeros(tuple(s.shape), jnp.float32), template, is_leaf=_STATE_LEAF
    )
    new_state = tx.init(params_like)
    if not isinstance(new_state, ChainState):
        raise TypeError(
            f"migrate_legacy_state targets ChainState layouts, got {type(new_state).__name__}"
        )

    field_map = dict(field_map or {})
    chain_fields = _namedtuple_fields(new_state)
    for k in list(moments):
        tgt = field_map.get(k, k)
        if tgt not in chain_fields and k == "m" and "trace" in chain_fields:
            tgt = "trace"  # SGDM momentum was renamed by the chain refactor
        field_map[k] = tgt
    unknown = [k for k, tgt in field_map.items() if k in moments and tgt not in chain_fields]
    if unknown:
        raise ValueError(
            f"legacy field(s) {sorted(unknown)} have no matching state field in "
            f"the target chain (available: {sorted(chain_fields)})"
        )
    by_field = {field_map[k]: v for k, v in moments.items()}

    def graft(node):
        if isinstance(node, ChainState):
            return ChainState(graft(s) for s in node.states)
        if isinstance(node, tuple) and hasattr(node, "_fields"):
            repl = {}
            for f in node._fields:
                v = getattr(node, f)
                if f in by_field:
                    want = jax.tree_util.tree_structure(v)
                    got = jax.tree_util.tree_structure(by_field[f])
                    if want != got:
                        raise ValueError(
                            f"legacy moment {f!r} does not match the target "
                            "chain's state structure — was the chain built "
                            "with the same quantization policies?\n"
                            f"  target: {str(want)[:300]}\n"
                            f"  legacy: {str(got)[:300]}"
                        )
                    repl[f] = by_field[f]
                elif f == "count" and step_val is not None:
                    repl[f] = jnp.asarray(step_val, jnp.int32)
                else:
                    repl[f] = graft(v)
            return node._replace(**repl)
        return node

    return graft(new_state)


def _namedtuple_fields(node, acc=None) -> set:
    """All NamedTuple field names reachable in a state tree (not leaves)."""
    from repro.core.optimizers.transform import ChainState

    acc = set() if acc is None else acc
    if isinstance(node, ChainState):
        for s in node.states:
            _namedtuple_fields(s, acc)
    elif isinstance(node, tuple) and hasattr(node, "_fields"):
        acc.update(node._fields)
        for v in node:
            _namedtuple_fields(v, acc)
    elif isinstance(node, (tuple, list)):
        for v in node:
            _namedtuple_fields(v, acc)
    return acc


class CheckpointManager:
    """Async keep-last-k manager: save() snapshots to host then writes on a
    background thread; the train loop never blocks on disk."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None, block: bool = False):
        self.wait()  # one in flight at a time
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)

        def _work():
            try:
                save_checkpoint(self.directory, step, host_tree, extra)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_work, daemon=True)
        self._thread.start()
        if block:
            self.wait()

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.directory)
            if n.startswith("step_")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True
            )

    def restore(self, target, step=None, shardings=None):
        self.wait()
        return restore_checkpoint(self.directory, target, step, shardings)
