"""Checkpointing facade: the I/O lives in ``repro.io`` (sharded per-host v2
format, async double-buffered writes, cross-mesh resharded restore, legacy
npz readable behind the manifest's format-version switch); this module keeps
the historical import surface plus the optimizer-state migration helper.

Checkpoints store the *compressed* optimizer state (packed 4-bit codes +
scales) directly — a 4-bit-AdamW checkpoint is ~7x smaller than an fp32-state
checkpoint — and the sharded format keeps it sharded through I/O: each host
writes only the shards it owns, restore assembles whatever layout is on disk
onto the current mesh.  See docs/checkpoints.md.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.optimizers.base import FactoredMoment
from repro.core.quantizer import QuantizedTensor
from repro.io import (  # noqa: F401  (re-exported public API)
    AsyncCheckpointWriter,
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
    tree_structure_repr,
)

__all__ = [
    "save_checkpoint",
    "restore_checkpoint",
    "latest_step",
    "CheckpointManager",
    "AsyncCheckpointWriter",
    "tree_structure_repr",
    "migrate_legacy_state",
]

_STATE_LEAF = lambda x: isinstance(x, (QuantizedTensor, FactoredMoment))


def migrate_legacy_state(dict_state: Dict, tx, field_map: Optional[Dict[str, str]] = None):
    """Convert a pre-chain dict optimizer state into ``ChainState`` layout.

    ``dict_state`` is the legacy layout (``{"m": <tree>, "v": <tree>,
    "step": <int32>}`` for AdamW-family; SGDM's momentum lived under ``"m"``),
    with moment leaves raw fp32, ``QuantizedTensor`` or ``FactoredMoment``.
    ``tx`` is the transform chain (or ``Optimizer`` facade) the state should
    feed — it must be built with the same quantization policies the legacy
    run used, which is checked structurally per moment tree.

    Returns ``tx.init``'s state with every moment tree replaced by the legacy
    values and every transform step counter set to the legacy ``"step"``
    (bias correction and schedules continue where the old run stopped).
    ``field_map`` renames legacy keys to chain state fields; the one rename
    the repo's own history needs (SGDM ``"m"`` -> ``"trace"``) is applied
    automatically.
    """
    from repro.core.optimizers.transform import ChainState

    moments = {k: v for k, v in dict_state.items() if k != "step"}
    if not moments:
        raise ValueError("legacy state has no moment trees to migrate")
    step_val = dict_state.get("step")

    # Rebuild a param-shaped tree of zeros from any moment tree: every leaf
    # kind (raw array / QuantizedTensor / FactoredMoment) knows its logical
    # shape, which is all ``init`` needs to re-derive structure + policies.
    template = next(iter(moments.values()))
    params_like = jax.tree_util.tree_map(
        lambda s: jnp.zeros(tuple(s.shape), jnp.float32), template, is_leaf=_STATE_LEAF
    )
    new_state = tx.init(params_like)
    if not isinstance(new_state, ChainState):
        raise TypeError(
            f"migrate_legacy_state targets ChainState layouts, got {type(new_state).__name__}"
        )

    field_map = dict(field_map or {})
    chain_fields = _namedtuple_fields(new_state)
    for k in list(moments):
        tgt = field_map.get(k, k)
        if tgt not in chain_fields and k == "m" and "trace" in chain_fields:
            tgt = "trace"  # SGDM momentum was renamed by the chain refactor
        field_map[k] = tgt
    unknown = [k for k, tgt in field_map.items() if k in moments and tgt not in chain_fields]
    if unknown:
        raise ValueError(
            f"legacy field(s) {sorted(unknown)} have no matching state field in "
            f"the target chain (available: {sorted(chain_fields)})"
        )
    by_field = {field_map[k]: v for k, v in moments.items()}

    def graft(node):
        if isinstance(node, ChainState):
            return ChainState(graft(s) for s in node.states)
        if isinstance(node, tuple) and hasattr(node, "_fields"):
            repl = {}
            for f in node._fields:
                v = getattr(node, f)
                if f in by_field:
                    want = jax.tree_util.tree_structure(v)
                    got = jax.tree_util.tree_structure(by_field[f])
                    if want != got:
                        raise ValueError(
                            f"legacy moment {f!r} does not match the target "
                            "chain's state structure — was the chain built "
                            "with the same quantization policies?\n"
                            f"  target: {str(want)[:300]}\n"
                            f"  legacy: {str(got)[:300]}"
                        )
                    repl[f] = by_field[f]
                elif f == "count" and step_val is not None:
                    repl[f] = jnp.asarray(step_val, jnp.int32)
                else:
                    repl[f] = graft(v)
            return node._replace(**repl)
        return node

    return graft(new_state)


def _namedtuple_fields(node, acc=None) -> set:
    """All NamedTuple field names reachable in a state tree (not leaves)."""
    from repro.core.optimizers.transform import ChainState

    acc = set() if acc is None else acc
    if isinstance(node, ChainState):
        for s in node.states:
            _namedtuple_fields(s, acc)
    elif isinstance(node, tuple) and hasattr(node, "_fields"):
        acc.update(node._fields)
        for v in node:
            _namedtuple_fields(v, acc)
    elif isinstance(node, (tuple, list)):
        for v in node:
            _namedtuple_fields(v, acc)
    return acc
