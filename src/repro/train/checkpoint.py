"""Checkpointing: atomic, async, content-hashed, keep-last-k.

Checkpoints store the *compressed* optimizer state (packed 4-bit codes +
scales) directly — a 4-bit-AdamW checkpoint is ~7x smaller than an fp32-state
checkpoint, which shrinks save/restore time and makes frequent checkpointing
(the first line of fault tolerance) cheap. Restore re-shards onto whatever
mesh is current, so an elastic restart with a different device count works
from the same files.

Layout:
    <dir>/step_000100/
        arrays.npz            # every array leaf, keyed by flattened path
        manifest.json         # treedef repr, shapes, dtypes, sha256 per leaf
    <dir>/LATEST              # atomically-updated pointer
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.optimizers.base import FactoredMoment
from repro.core.quantizer import QuantizedTensor

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step", "CheckpointManager"]

_STATE_LEAF = lambda x: isinstance(x, (QuantizedTensor, FactoredMoment))


def _flatten_with_paths(tree) -> List[Tuple[str, np.ndarray]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out.append((key, np.asarray(leaf)))
    return out


def _sha(a: np.ndarray) -> str:
    return hashlib.sha256(a.tobytes()).hexdigest()[:16]


def save_checkpoint(directory: str, step: int, tree: Any, extra: Optional[Dict] = None) -> str:
    """Atomic save: write to tmp dir, fsync, rename, update LATEST."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        leaves = _flatten_with_paths(tree)
        arrays = {f"a{i}": arr for i, (_, arr) in enumerate(leaves)}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "extra": extra or {},
            "leaves": [
                {
                    "key": key,
                    "name": f"a{i}",
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "sha256": _sha(arr),
                }
                for i, (key, arr) in enumerate(leaves)
            ],
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    # atomic LATEST pointer
    latest_tmp = os.path.join(directory, ".LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(str(step))
        f.flush()
        os.fsync(f.fileno())
    os.replace(latest_tmp, os.path.join(directory, "LATEST"))
    return final


def latest_step(directory: str) -> Optional[int]:
    p = os.path.join(directory, "LATEST")
    if not os.path.exists(p):
        return None
    return int(open(p).read().strip())


def restore_checkpoint(
    directory: str,
    target: Any,
    step: Optional[int] = None,
    shardings: Any = None,
    validate: bool = True,
) -> Tuple[Any, Dict]:
    """Restore into the structure of ``target`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings`` (same structure) re-shards every leaf
    onto the current mesh — elastic restart across device counts."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    manifest = json.load(open(os.path.join(d, "manifest.json")))
    npz = np.load(os.path.join(d, "arrays.npz"))

    flat_target = jax.tree_util.tree_flatten_with_path(target)
    paths = [jax.tree_util.keystr(p) for p, _ in flat_target[0]]
    by_key = {m["key"]: m for m in manifest["leaves"]}

    sh_leaves = None
    if shardings is not None:
        sh_leaves = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
        )

    out = []
    for i, key in enumerate(paths):
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key}")
        m = by_key[key]
        arr = npz[m["name"]]
        if validate and _sha(arr) != m["sha256"]:
            raise IOError(f"checkpoint corruption at {key} (hash mismatch)")
        if sh_leaves is not None:
            out.append(jax.device_put(arr, sh_leaves[i]))
        else:
            out.append(jnp.asarray(arr))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(target), out
    )
    return tree, manifest["extra"]


class CheckpointManager:
    """Async keep-last-k manager: save() snapshots to host then writes on a
    background thread; the train loop never blocks on disk."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None, block: bool = False):
        self.wait()  # one in flight at a time
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)

        def _work():
            try:
                save_checkpoint(self.directory, step, host_tree, extra)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_work, daemon=True)
        self._thread.start()
        if block:
            self.wait()

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.directory)
            if n.startswith("step_")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True
            )

    def restore(self, target, step=None, shardings=None):
        self.wait()
        return restore_checkpoint(self.directory, target, step, shardings)
