"""Deterministic synthetic token pipeline, host-sharded and elastic.

Addressing is (seed, step, host_index, num_hosts): any host subset can
reproduce its shard after an elastic rescale — no shared state, no cursor
files beyond the step number already in the checkpoint. The synthetic stream
is a Zipf-ish unigram mix with enough structure (local n-gram correlations)
that perplexity meaningfully decreases during the example runs.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import numpy as np

__all__ = ["DataConfig", "SyntheticLM", "host_batch"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0


class SyntheticLM:
    """Markov-flavored synthetic LM stream: token_t depends on token_{t-1}
    through a fixed random permutation mixed with Zipf noise."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        self.perm = rng.permutation(v)
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = 1.0 / ranks**1.1
        self.unigram = p / p.sum()

    def batch_at(self, step: int, host: int = 0, num_hosts: int = 1) -> Dict[str, np.ndarray]:
        """The (deterministic) host-local slice of the global batch at step."""
        cfg = self.cfg
        assert cfg.global_batch % num_hosts == 0
        local = cfg.global_batch // num_hosts
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, host, num_hosts])
        )
        base = rng.choice(cfg.vocab_size, size=(local, cfg.seq_len), p=self.unigram)
        toks = base.copy()
        # inject first-order structure: with prob .5, token = perm[prev]
        use_prev = rng.random((local, cfg.seq_len)) < 0.5
        toks[:, 1:] = np.where(
            use_prev[:, 1:], self.perm[toks[:, :-1]], toks[:, 1:]
        )
        labels = np.concatenate(
            [toks[:, 1:], np.full((local, 1), -1, np.int64)], axis=1
        )
        return {
            "tokens": toks.astype(np.int32),
            "labels": labels.astype(np.int32),
        }

    def iterate(self, start_step: int = 0, host: int = 0, num_hosts: int = 1) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step, host, num_hosts)
            step += 1


def host_batch(stream: SyntheticLM, step: int, mesh=None) -> Dict[str, np.ndarray]:
    """Single-process convenience: the whole global batch on this host."""
    return stream.batch_at(step, host=0, num_hosts=1)
