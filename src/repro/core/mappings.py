"""Quantization mappings T: code -> [0,1] (or [-1,1] signed), as a registry.

A *mapping* is materialized as a sorted fp32 table of length <= 2^b.  Encoding
is round-to-nearest via midpoint comparison (branchless, TPU friendly) with an
optional stochastic-rounding variant (App. E.3).

Every map enters the system through ``register_mapping(name, table_fn)`` —
including the paper's three (App. E.2), registered at the bottom of this
module.  ``QuantConfig`` validates its ``mapping`` string against
``registered()`` at construction, so the registry is the single source of
truth for what maps exist; there is no parallel hardcoded list.

Registered maps:

* ``linear``   — T(i) = (i+1)/2^b, zero EXCLUDED by construction (used for the
  second moment; smallest representable value at 4 bits is 1/16 = 0.0625).
* ``de``       — dynamic exponent mapping [Dettmers 2015] with the bitsandbytes
  corner cases: unsigned code 0 -> 0.0, unsigned code 1 -> 1.0; in the signed
  case the (sign=1, magnitude=0) pattern is repurposed as +1.0, so -1.0 is not
  representable and the map is asymmetric (App. E.2).
* ``de0``      — ``de`` with the zero code removed (the paper's DE-0), leaving
  2^b - 1 quantization points; fixes the second-moment zero-point problem at
  the cost of one wasted code.
* ``dynamic``  — bitsandbytes' symmetric dynamic map: a sign bit plus
  dynamic-exponent magnitudes (with 0.0 and 1.0 representable on BOTH sides),
  the create_dynamic_map construction.  Unlike ``de`` it is exactly odd
  symmetric — the natural choice for Shampoo's Kronecker factors, whose
  off-diagonal entries carry meaningful signs in both directions.  (The
  unsigned table coincides with ``de``: with no sign bit the constructions
  agree.)
* ``quantile`` — static quantile map: code points at equally spaced quantiles
  of N(0,1) (clipped at the 99.5th percentile, normalized to max 1), the
  static analogue of bitsandbytes' quantile quantization / NF4.  Unsigned is
  the half-normal version — strictly positive (zero-excluding), a
  quantile-spaced alternative second-moment map.
* ``log-ema``  — SOLO-style logarithmic map for EMA statistics: code points
  log-uniform over ``bits`` decades ending at 1.0, so after absmax
  normalization the relative quantization error is constant across magnitudes
  — tuned for EMA accumulators whose entries span orders of magnitude.
  Unsigned excludes zero; signed is symmetric with a zero code.
"""

from __future__ import annotations

import dataclasses
import difflib
import functools
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "MappingSpec",
    "register_mapping",
    "registered",
    "get_spec",
    "mapping_table",
    "encode",
    "decode",
    "encode_stochastic",
    "encode_stochastic_uniform",
]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MappingSpec:
    """A registered quantization map.

    ``table_fn(bits, signed)`` returns the sorted, unique table of
    quantization points as a float numpy array of length <= 2^bits.
    ``symmetric_signed`` declares that the signed table is exactly odd
    symmetric (``de``/``de0`` are famously not — their +1.0 code has no
    negative twin); the registry contract tests enforce the declaration.
    Remaining fields are documentation surfaced by ``QuantConfig.name`` and
    the docs/optimizers.md map table.
    """

    name: str
    table_fn: Callable[[int, bool], np.ndarray]
    display: str
    statistic: str = ""
    zero_code: str = ""
    symmetric_signed: bool = True
    reference: str = ""


_REGISTRY: Dict[str, MappingSpec] = {}


def register_mapping(
    name: str,
    table_fn: Callable[[int, bool], np.ndarray],
    *,
    display: str = "",
    statistic: str = "",
    zero_code: str = "",
    symmetric_signed: bool = True,
    reference: str = "",
) -> MappingSpec:
    """Register a quantization map — the ONLY way a map becomes usable in a
    ``QuantConfig`` (and hence anywhere a config flows: optimizer moments,
    gradient transport, q4 serving weights)."""
    if not isinstance(name, str) or not name:
        raise ValueError(f"mapping name must be a non-empty string, got {name!r}")
    if name in _REGISTRY:
        raise ValueError(f"mapping {name!r} is already registered")
    spec = MappingSpec(
        name=name,
        table_fn=table_fn,
        display=display or name,
        statistic=statistic,
        zero_code=zero_code,
        symmetric_signed=symmetric_signed,
        reference=reference,
    )
    _REGISTRY[name] = spec
    return spec


def registered() -> Tuple[str, ...]:
    """Names of all registered maps, in registration order."""
    return tuple(_REGISTRY)


def get_spec(name: str) -> MappingSpec:
    """Resolve a mapping name, with a did-you-mean on typos."""
    spec = _REGISTRY.get(name)
    if spec is None:
        hint = ""
        close = difflib.get_close_matches(str(name), _REGISTRY, n=1)
        if close:
            hint = f" — did you mean {close[0]!r}?"
        raise ValueError(
            f"unknown mapping {name!r}; registered mappings: {registered()}"
            f"{hint} (add new maps with repro.core.mappings.register_mapping)"
        )
    return spec


# ---------------------------------------------------------------------------
# table builders
# ---------------------------------------------------------------------------


def _de_fraction_levels(F: int) -> np.ndarray:
    """Midpoint fraction levels for F fraction bits, distributed in (0.1, 1)."""
    j = np.arange(2**F + 1, dtype=np.float64)
    p = (1.0 - 0.1) / (2**F) * j + 0.1
    return (p[:-1] + p[1:]) / 2.0


def _de_unsigned_values(width: int, special_one: bool = True) -> np.ndarray:
    """All dynamic-exponent values for ``width``-bit unsigned codes.

    Code 0 -> 0.0 and (if ``special_one``) code 1 -> 1.0, the bitsandbytes
    corner cases; otherwise the code's binary representation is
    [E leading zeros | 1 | F fraction bits] and the value is
    10^-E * fraction[F]. In the signed case only the all-zeros pattern is
    special (App. E.2), so magnitudes are built with ``special_one=False``.
    """
    values = np.zeros(2**width, dtype=np.float64)
    values[0] = 0.0
    start = 1
    if special_one:
        values[1] = 1.0
        start = 2
    for code in range(start, 2**width):
        bits = format(code, f"0{width}b")
        E = len(bits) - len(bits.lstrip("0"))  # leading zeros
        frac_bits = bits[E + 1 :]
        F = len(frac_bits)
        k = int(frac_bits, 2) if F > 0 else 0
        frac = _de_fraction_levels(F)[k]
        values[code] = (10.0**-E) * frac
    return values


def _linear_table(bits: int, signed: bool) -> np.ndarray:
    if signed:
        # Symmetric signed linear map excluding zero: +/- (i+1)/2^(b-1).
        half = (np.arange(2 ** (bits - 1), dtype=np.float64) + 1) / 2 ** (bits - 1)
        return np.concatenate([-half[::-1], half])
    return (np.arange(2**bits, dtype=np.float64) + 1) / 2**bits


def _de_table(bits: int, signed: bool) -> np.ndarray:
    if signed:
        mag = _de_unsigned_values(bits - 1, special_one=False)
        # sign=0 patterns: +mag (pattern 0 -> 0.0). sign=1 patterns: -mag,
        # except magnitude-pattern 0 which is repurposed as +1.0, so -1.0 is
        # not representable (the map is asymmetric, App. E.2).
        vals = np.concatenate([mag, np.array([1.0]), -mag[1:]])
    else:
        vals = _de_unsigned_values(bits)
    return np.sort(np.unique(vals))


def _de0_table(bits: int, signed: bool) -> np.ndarray:
    vals = _de_table(bits, signed)
    return vals[vals != 0.0]


def _dynamic_table(bits: int, signed: bool) -> np.ndarray:
    if signed:
        # Sign bit + (bits-1)-bit dynamic-exponent magnitude with BOTH corner
        # cases (0.0 and 1.0 representable) on both sides; +0/-0 collapse, so
        # the table has 2^bits - 1 entries and is exactly odd symmetric.
        mag = _de_unsigned_values(bits - 1, special_one=True)
        return np.sort(np.unique(np.concatenate([-mag, mag])))
    return np.sort(np.unique(_de_unsigned_values(bits)))


def _quantile_table(bits: int, signed: bool) -> np.ndarray:
    from statistics import NormalDist

    inv_cdf = NormalDist().inv_cdf
    P = 0.995  # clip the unbounded normal tails at the 99.5th percentile
    if signed:
        K = 2 ** (bits - 1) - 1
        pos = np.array(
            [inv_cdf(0.5 + 0.5 * P * (i + 1) / K) for i in range(K)], np.float64
        )
        pos /= pos[-1]
        return np.concatenate([-pos[::-1], [0.0], pos])
    K = 2**bits
    vals = np.array(
        [inv_cdf(0.5 + 0.5 * P * (i + 1) / K) for i in range(K)], np.float64
    )
    return vals / vals[-1]


def _log_ema_table(bits: int, signed: bool) -> np.ndarray:
    # Log-uniform code points over `bits` decades ending at 1.0: constant
    # RELATIVE quantization error across magnitudes, the regime that matters
    # for EMA accumulators whose entries span orders of magnitude (SOLO).
    decades = float(bits)
    if signed:
        K = 2 ** (bits - 1) - 1
        pos = 10.0 ** (-decades * (1.0 - (np.arange(K, dtype=np.float64) + 1.0) / K))
        return np.concatenate([-pos[::-1], [0.0], pos])
    K = 2**bits
    return 10.0 ** (-decades * (1.0 - (np.arange(K, dtype=np.float64) + 1.0) / K))


# ---------------------------------------------------------------------------
# table materialization + codecs
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _mapping_table_np(kind: str, bits: int, signed: bool) -> np.ndarray:
    """Sorted numpy table of quantization points for (kind, bits, signed).

    Looks the map up in the registry and enforces the table contract
    (sorted, unique, finite, length <= 2^bits) on whatever the builder
    returns — a misbehaving ``register_mapping`` fails here, not downstream
    in a kernel.
    """
    spec = get_spec(kind)
    if bits < 2 or bits > 8:
        raise ValueError(f"bits must be in [2, 8], got {bits}")
    vals = np.asarray(spec.table_fn(bits, signed), dtype=np.float64).astype(np.float32)
    if vals.ndim != 1 or vals.size == 0 or vals.size > 2**bits:
        raise ValueError(
            f"mapping {kind!r}: table must be 1-d with 1..2^{bits} entries, "
            f"got shape {vals.shape}"
        )
    if not np.all(np.isfinite(vals)):
        raise ValueError(f"mapping {kind!r}: table contains non-finite values")
    if not np.all(np.diff(vals) > 0):
        raise ValueError(f"mapping {kind!r}: table must be strictly increasing")
    return vals


def mapping_table(kind: str, bits: int, signed: bool) -> jnp.ndarray:
    """Return the sorted fp32 quantization-point table as a jnp array."""
    return jnp.asarray(_mapping_table_np(kind, bits, signed))


def _midpoints(table: jnp.ndarray) -> jnp.ndarray:
    return (table[1:] + table[:-1]) / 2.0


def encode(n: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """Round-to-nearest code indices into ``table`` (branchless).

    idx = sum_k [n > midpoint_k]; exact round-to-nearest for a sorted table
    (ties go to the lower code, matching argmin-first behaviour).
    """
    mids = _midpoints(table)
    # (..., 1) > (K-1,) -> (..., K-1); sum over the last axis.
    idx = jnp.sum(n[..., None] > mids, axis=-1)
    return idx.astype(jnp.uint8)


def decode(codes: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """Dequantize code indices back to fp32 quantization points."""
    return jnp.take(table, codes.astype(jnp.int32), axis=0)


def encode_stochastic(
    n: jnp.ndarray, table: jnp.ndarray, key: jax.Array
) -> jnp.ndarray:
    """Stochastic rounding (App. E.3): round to the bracketing codes with
    probability proportional to proximity; values outside the table clamp."""
    return encode_stochastic_uniform(n, table, jax.random.uniform(key, n.shape))


def encode_stochastic_uniform(
    n: jnp.ndarray, table: jnp.ndarray, u: jnp.ndarray
) -> jnp.ndarray:
    """``encode_stochastic`` consuming precomputed uniforms ``u`` in [0, 1).

    Callers that need mesh-invariant noise (gradient transport in
    ``repro.comms``) derive ``u`` with the counter-based Threefry of
    ``repro.kernels.sr`` instead of ``jax.random.uniform``, whose draws
    depend on the output sharding under the default non-partitionable
    lowering.
    """
    k = table.shape[0]
    # Lower bracket: largest code with T(code) <= n (clamped to [0, K-2]).
    lo = jnp.clip(jnp.sum(n[..., None] >= table, axis=-1) - 1, 0, k - 2)
    t_lo = jnp.take(table, lo, axis=0)
    t_hi = jnp.take(table, lo + 1, axis=0)
    span = jnp.maximum(t_hi - t_lo, 1e-12)
    p_hi = jnp.clip((n - t_lo) / span, 0.0, 1.0)
    idx = lo + (u < p_hi).astype(lo.dtype)
    return idx.astype(jnp.uint8)


# ---------------------------------------------------------------------------
# the built-in maps — registered like any third-party map would be
# ---------------------------------------------------------------------------

register_mapping(
    "linear",
    _linear_table,
    display="Linear",
    statistic="second moment (EMA of squared grads)",
    zero_code="zero excluded by construction (both signednesses)",
    symmetric_signed=True,
    reference="4-bit Optimizers App. E.2",
)
register_mapping(
    "de",
    _de_table,
    display="DE",
    statistic="first moment / signed zero-clustered tensors",
    zero_code="unsigned has 0.0; signed repurposes -0 as +1.0 (asymmetric)",
    symmetric_signed=False,
    reference="Dettmers 2015; 4-bit Optimizers App. E.2",
)
register_mapping(
    "de0",
    _de0_table,
    display="DE-0",
    statistic="second moment (zero-point fix)",
    zero_code="zero code removed from DE (2^b - 1 points)",
    symmetric_signed=False,
    reference="4-bit Optimizers App. E.2 (DE-0)",
)
register_mapping(
    "dynamic",
    _dynamic_table,
    display="Dyn",
    statistic="signed matrix factors (Shampoo Kronecker blocks)",
    zero_code="zero representable; signed exactly odd symmetric with ±1.0",
    symmetric_signed=True,
    reference="bitsandbytes create_dynamic_map; 4-bit Shampoo",
)
register_mapping(
    "quantile",
    _quantile_table,
    display="Qtl",
    statistic="normally distributed moments / weights",
    zero_code="signed has a zero code; unsigned strictly positive",
    symmetric_signed=True,
    reference="bitsandbytes quantile quantization; QLoRA NF4",
)
register_mapping(
    "log-ema",
    _log_ema_table,
    display="LogEMA",
    statistic="EMA statistics spanning decades (second moment)",
    zero_code="unsigned zero-excluding; signed symmetric with a zero code",
    symmetric_signed=True,
    reference="SOLO (logarithmic quantization for EMA dynamics)",
)
