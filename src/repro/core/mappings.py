"""Quantization mappings T: code -> [0,1] (or [-1,1] signed).

Implements the three mappings used in the paper (App. E.2):

* ``linear``  — T(i) = (i+1)/2^b, zero EXCLUDED by construction (used for the
  second moment; smallest representable value at 4 bits is 1/16 = 0.0625).
* ``de``      — dynamic exponent mapping [Dettmers 2015] with the bitsandbytes
  corner cases: unsigned code 0 -> 0.0, unsigned code 1 -> 1.0; in the signed
  case the (sign=1, magnitude=0) pattern is repurposed as +1.0, so -1.0 is not
  representable and the map is asymmetric (App. E.2).
* ``de0``     — ``de`` with the zero code removed (the paper's DE-0), leaving
  2^b - 1 quantization points; fixes the second-moment zero-point problem at
  the cost of one wasted code.

A mapping is materialized as a sorted fp32 table of length <= 2^b. Encoding is
round-to-nearest via midpoint comparison (branchless, TPU friendly) with an
optional stochastic-rounding variant (App. E.3).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "mapping_table",
    "encode",
    "decode",
    "encode_stochastic",
    "encode_stochastic_uniform",
    "MAPPINGS",
]

MAPPINGS = ("linear", "de", "de0")


def _de_fraction_levels(F: int) -> np.ndarray:
    """Midpoint fraction levels for F fraction bits, distributed in (0.1, 1)."""
    j = np.arange(2**F + 1, dtype=np.float64)
    p = (1.0 - 0.1) / (2**F) * j + 0.1
    return (p[:-1] + p[1:]) / 2.0


def _de_unsigned_values(width: int, special_one: bool = True) -> np.ndarray:
    """All dynamic-exponent values for ``width``-bit unsigned codes.

    Code 0 -> 0.0 and (if ``special_one``) code 1 -> 1.0, the bitsandbytes
    corner cases; otherwise the code's binary representation is
    [E leading zeros | 1 | F fraction bits] and the value is
    10^-E * fraction[F]. In the signed case only the all-zeros pattern is
    special (App. E.2), so magnitudes are built with ``special_one=False``.
    """
    values = np.zeros(2**width, dtype=np.float64)
    values[0] = 0.0
    start = 1
    if special_one:
        values[1] = 1.0
        start = 2
    for code in range(start, 2**width):
        bits = format(code, f"0{width}b")
        E = len(bits) - len(bits.lstrip("0"))  # leading zeros
        frac_bits = bits[E + 1 :]
        F = len(frac_bits)
        k = int(frac_bits, 2) if F > 0 else 0
        frac = _de_fraction_levels(F)[k]
        values[code] = (10.0**-E) * frac
    return values


@functools.lru_cache(maxsize=None)
def _mapping_table_np(kind: str, bits: int, signed: bool) -> np.ndarray:
    """Sorted numpy table of quantization points for (kind, bits, signed)."""
    if kind not in MAPPINGS:
        raise ValueError(f"unknown mapping kind {kind!r}; want one of {MAPPINGS}")
    if bits < 2 or bits > 8:
        raise ValueError(f"bits must be in [2, 8], got {bits}")

    if kind == "linear":
        if signed:
            # Symmetric signed linear map excluding zero: +/- (i+1)/2^(b-1).
            half = (np.arange(2 ** (bits - 1), dtype=np.float64) + 1) / 2 ** (bits - 1)
            vals = np.concatenate([-half[::-1], half])
        else:
            vals = (np.arange(2**bits, dtype=np.float64) + 1) / 2**bits
        return np.sort(vals).astype(np.float32)

    # dynamic exponent ("de" / "de0")
    if signed:
        mag = _de_unsigned_values(bits - 1, special_one=False)
        # sign=0 patterns: +mag (pattern 0 -> 0.0). sign=1 patterns: -mag,
        # except magnitude-pattern 0 which is repurposed as +1.0, so -1.0 is
        # not representable (the map is asymmetric, App. E.2).
        vals = np.concatenate([mag, np.array([1.0]), -mag[1:]])
    else:
        vals = _de_unsigned_values(bits)
    vals = np.sort(np.unique(vals))
    if kind == "de0":
        vals = vals[vals != 0.0]
    return vals.astype(np.float32)


def mapping_table(kind: str, bits: int, signed: bool) -> jnp.ndarray:
    """Return the sorted fp32 quantization-point table as a jnp array."""
    return jnp.asarray(_mapping_table_np(kind, bits, signed))


def _midpoints(table: jnp.ndarray) -> jnp.ndarray:
    return (table[1:] + table[:-1]) / 2.0


def encode(n: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """Round-to-nearest code indices into ``table`` (branchless).

    idx = sum_k [n > midpoint_k]; exact round-to-nearest for a sorted table
    (ties go to the lower code, matching argmin-first behaviour).
    """
    mids = _midpoints(table)
    # (..., 1) > (K-1,) -> (..., K-1); sum over the last axis.
    idx = jnp.sum(n[..., None] > mids, axis=-1)
    return idx.astype(jnp.uint8)


def decode(codes: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """Dequantize code indices back to fp32 quantization points."""
    return jnp.take(table, codes.astype(jnp.int32), axis=0)


def encode_stochastic(
    n: jnp.ndarray, table: jnp.ndarray, key: jax.Array
) -> jnp.ndarray:
    """Stochastic rounding (App. E.3): round to the bracketing codes with
    probability proportional to proximity; values outside the table clamp."""
    return encode_stochastic_uniform(n, table, jax.random.uniform(key, n.shape))


def encode_stochastic_uniform(
    n: jnp.ndarray, table: jnp.ndarray, u: jnp.ndarray
) -> jnp.ndarray:
    """``encode_stochastic`` consuming precomputed uniforms ``u`` in [0, 1).

    Callers that need mesh-invariant noise (gradient transport in
    ``repro.comms``) derive ``u`` with the counter-based Threefry of
    ``repro.kernels.sr`` instead of ``jax.random.uniform``, whose draws
    depend on the output sharding under the default non-partitionable
    lowering.
    """
    k = table.shape[0]
    # Lower bracket: largest code with T(code) <= n (clamped to [0, K-2]).
    lo = jnp.clip(jnp.sum(n[..., None] >= table, axis=-1) - 1, 0, k - 2)
    t_lo = jnp.take(table, lo, axis=0)
    t_hi = jnp.take(table, lo + 1, axis=0)
    span = jnp.maximum(t_hi - t_lo, 1e-12)
    p_hi = jnp.clip((n - t_lo) / span, 0.0, 1.0)
    idx = lo + (u < p_hi).astype(lo.dtype)
    return idx.astype(jnp.uint8)
