"""Quantizer Q = M ∘ N and the QuantizedTensor pytree container.

This is the paper's Sec. 2.2 formulation made concrete:

    codes = M_{T,b}( N(x) )         (compress)
    x~    = N^{-1}( T(codes) )      (decompress)

``QuantConfig`` names a quantizer the way the paper does (Norm./Map.), e.g.
B128/DE  == QuantConfig(normalization="blockwise", block_size=128, mapping="de")
Rank-1/Linear == QuantConfig(normalization="rank1", mapping="linear").

4-bit codes are stored nibble-packed (two per uint8); 8-bit codes are stored
raw. Tensors with <= ``threshold`` elements (default 4096, App. D.1) are kept
in fp32 by the pytree-level helpers.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import mappings, normalization, packing

__all__ = [
    "QuantConfig",
    "QuantizedTensor",
    "quantize",
    "dequantize",
    "quantized_nbytes",
]


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Static description of a quantizer (hashable; safe as pytree aux data).

    ``mapping`` must name a map in the ``repro.core.mappings`` registry
    (``mappings.registered()``); unknown names fail here, at construction,
    with a did-you-mean — not later inside a traced update.
    """

    bits: int = 4
    normalization: str = "blockwise"  # pertensor | blockwise | rank1
    block_size: int = 128
    mapping: str = "de"  # any name in mappings.registered()
    signed: bool = True
    stochastic_rounding: bool = False
    threshold: int = 4096

    def __post_init__(self):
        mappings.get_spec(self.mapping)  # raises listing mappings.registered()

    @property
    def name(self) -> str:
        norm = {
            "pertensor": "PerTensor",
            "blockwise": f"B{self.block_size}",
            "rank1": "Rank-1",
        }[self.normalization]
        mp = mappings.get_spec(self.mapping).display
        sr = "+SR" if self.stochastic_rounding else ""
        return f"{norm}/{mp}{sr}@{self.bits}bit"

    def table(self) -> jnp.ndarray:
        return mappings.mapping_table(self.mapping, self.bits, self.signed)


# Paper-named quantizer presets.
B2048_DE = QuantConfig(normalization="blockwise", block_size=2048, mapping="de")
B128_DE = QuantConfig(normalization="blockwise", block_size=128, mapping="de")
B128_DE0 = QuantConfig(
    normalization="blockwise", block_size=128, mapping="de0", signed=False
)
RANK1_LINEAR = QuantConfig(normalization="rank1", mapping="linear", signed=False)


@jax.tree_util.register_pytree_with_keys_class
class QuantizedTensor:
    """Compressed tensor: packed codes + normalization scales + static meta."""

    def __init__(
        self,
        codes: jnp.ndarray,
        scales: Tuple[jnp.ndarray, ...],
        shape: Tuple[int, ...],
        config: QuantConfig,
    ):
        self.codes = codes
        self.scales = scales
        self.shape = tuple(shape)
        self.config = config

    # -- pytree protocol --------------------------------------------------
    def tree_flatten_with_keys(self):
        k = jax.tree_util.GetAttrKey
        return (
            (k("codes"), self.codes),
            (k("scales"), self.scales),
        ), (self.shape, self.config)

    @classmethod
    def tree_unflatten(cls, aux, children):
        codes, scales = children
        shape, config = aux
        return cls(codes, scales, shape, config)

    # ----------------------------------------------------------------------
    @property
    def size(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n

    def nbytes(self) -> int:
        """Persistent storage cost in bytes (codes + scales)."""
        total = self.codes.size * self.codes.dtype.itemsize
        for s in self.scales:
            total += s.size * s.dtype.itemsize
        return int(total)

    def __repr__(self) -> str:  # pragma: no cover
        return f"QuantizedTensor(shape={self.shape}, {self.config.name})"


def _normalize(x: jnp.ndarray, config: QuantConfig):
    if config.normalization == "pertensor":
        n, s = normalization.pertensor_normalize(x)
        return n, (s,)
    if config.normalization == "blockwise":
        n, s = normalization.blockwise_normalize(x, config.block_size)
        return n, (s,)
    if config.normalization == "rank1":
        n, stats = normalization.rank1_normalize(x)
        return n, tuple(stats)
    raise ValueError(f"unknown normalization {config.normalization!r}")


def _denorm_scale(
    scales: Tuple[jnp.ndarray, ...], shape: Tuple[int, ...], config: QuantConfig
) -> jnp.ndarray:
    if config.normalization == "pertensor":
        return normalization.pertensor_denorm(scales[0], shape)
    if config.normalization == "blockwise":
        return normalization.blockwise_denorm(scales[0], shape, config.block_size)
    if config.normalization == "rank1":
        if len(shape) <= 1:
            return normalization.pertensor_denorm(scales[0], shape)
        return normalization.rank1_denorm(scales, shape)
    raise ValueError(f"unknown normalization {config.normalization!r}")


def quantize(
    x: jnp.ndarray,
    config: QuantConfig,
    key: Optional[jax.Array] = None,
    *,
    uniforms: Optional[jnp.ndarray] = None,
) -> QuantizedTensor:
    """Compress a tensor. ``key`` is required iff stochastic_rounding.

    ``uniforms`` (same shape as ``x``, values in [0, 1)) overrides the
    ``jax.random`` draw for stochastic rounding — callers that must be
    bit-reproducible across mesh layouts (``repro.comms``) pass
    counter-based Threefry uniforms here.
    """
    x = x.astype(jnp.float32)
    n, scales = _normalize(x, config)
    table = config.table()
    if config.stochastic_rounding and uniforms is not None:
        codes = mappings.encode_stochastic_uniform(n, table, uniforms)
    elif config.stochastic_rounding and key is not None:
        codes = mappings.encode_stochastic(n, table, key)
    else:
        # Round-to-nearest; also the fallback when an SR config is used
        # without a PRNG key (e.g. when quantizing deterministic zeros at init).
        codes = mappings.encode(n, table)
    if config.bits == 4:
        codes = packing.pack4(codes)  # packs along the last axis
    return QuantizedTensor(codes, scales, x.shape, config)


def dequantize(q: QuantizedTensor) -> jnp.ndarray:
    """Decompress back to fp32 (the paper's N^{-1} ∘ T)."""
    config = q.config
    codes = q.codes
    if config.bits == 4:
        codes = packing.unpack4(codes, q.shape[-1])
    codes = codes.reshape(q.shape)
    vals = mappings.decode(codes, config.table())
    scale = _denorm_scale(q.scales, q.shape, config)
    return vals * scale


def quantized_nbytes(shape: Tuple[int, ...], config: QuantConfig) -> int:
    """Bytes of the compressed form of a ``shape`` tensor under ``config``,
    from shapes alone (no allocation) — codes plus fp32 scales.  This is the
    storage cost of ``quantize(x, config)`` and equally the wire cost of
    moving the compressed payload through a collective (``repro.comms``)."""
    from repro.core import normalization, packing

    shape = tuple(int(d) for d in shape)
    n = 1
    for d in shape:
        n *= d
    if n == 0:
        return 0
    if config.bits == 4:
        last = shape[-1] if shape else 1
        codes = (n // last) * packing.packed_last_dim(last)
    else:
        codes = n  # one uint8 code per element
    if config.normalization == "pertensor":
        scales = 1
    elif config.normalization == "blockwise":
        scales = normalization.blockwise_num_blocks(n, config.block_size)
    elif config.normalization == "rank1":
        scales = sum(shape) if len(shape) >= 2 else 1
    else:
        raise ValueError(f"unknown normalization {config.normalization!r}")
    return int(codes + scales * 4)


def state_bytes(x: Any) -> int:
    """Persistent bytes of an optimizer-state leaf (quantized or raw)."""
    if isinstance(x, QuantizedTensor):
        return x.nbytes()
    return int(x.size * x.dtype.itemsize)
