"""Nibble packing: two 4-bit codes per uint8, packed along the last axis.

TPU adaptation note: codes are packed pairwise along the *last* (lane) axis
(low nibble = even index, high nibble = odd index), so the packed tensor keeps
the parameter's leading dims: a (n, m) code tensor packs to (n, ceil(m/2)).
This keeps optimizer-state layouts aligned with parameter sharding (ZeRO
shards the leading dim) and makes unpacking a vectorizable shift/mask on VREG
lanes — no gathers. Odd last dims are zero-padded; callers track the logical
size.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["pack4", "unpack4", "packed_last_dim"]


def packed_last_dim(last: int) -> int:
    return (last + 1) // 2


def pack4(codes: jnp.ndarray) -> jnp.ndarray:
    """Pack uint8 4-bit codes (values < 16) pairwise along the last axis."""
    last = codes.shape[-1]
    if last % 2:
        pad = [(0, 0)] * (codes.ndim - 1) + [(0, 1)]
        codes = jnp.pad(codes, pad)
    lo = codes[..., 0::2].astype(jnp.uint8)
    hi = codes[..., 1::2].astype(jnp.uint8)
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack4(packed: jnp.ndarray, last: int) -> jnp.ndarray:
    """Unpack bytes back into uint8 codes with logical last dim ``last``."""
    lo = packed & jnp.uint8(0x0F)
    hi = (packed >> 4) & jnp.uint8(0x0F)
    interleaved = jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1], -1)
    return interleaved[..., :last]
