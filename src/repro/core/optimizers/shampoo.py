"""Shampoo family as transformation chains (*4-bit Shampoo*, PAPERS.md).

* fp32 Shampoo oracle — ``shampoo32(lr)``: blocked Kronecker preconditioners
  (``scale_by_shampoo``) with AdamW grafting, nothing compressed.  The
  trajectory-parity reference for the 4-bit variant.
* 4-bit Shampoo       — ``shampoo4bit(lr)``: the SAME chain with the four
  Kronecker factor trees (L/R statistics + their inverse roots) held as
  4-bit ``QuantizedTensor``s through ``compressed()`` — blockwise B128 with
  the symmetric ``dynamic`` map (factors carry signs both ways, so the
  asymmetric DE map is wrong for them) — and the grafting moments on the
  paper's 4-bit AdamW recipe (m B128/DE, v Rank-1/Linear).

``compressed()`` treats the factor trees exactly like first-order moments:
decompress -> ``scale_by_shampoo`` -> recompress is Alg. 1 verbatim, just
over six state fields instead of two.  No kernel route is attached: the
fused Pallas path computes a *whole* AdamW step and emits ``Replace``
leaves, which would silently drop the preconditioning — the grafting
moments intentionally keep the kernel-ELIGIBLE layout (B128 m + rank-1 v)
so a future preconditioned kernel can take over without a state migration
(tests/test_shampoo.py pins both facts).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.optimizers.adamw import M_4BIT, V_4BIT
from repro.core.optimizers.base import Optimizer, QuantPolicy
from repro.core.optimizers.transform import (
    Schedule,
    add_decayed_weights,
    as_optimizer,
    chain,
    compressed,
    scale_by_learning_rate,
    scale_by_shampoo,
)
from repro.core.quantizer import QuantConfig

__all__ = ["FACTOR_4BIT", "shampoo_chain", "shampoo32", "shampoo4bit"]

# Kronecker-factor quantizer (4-bit Shampoo): blockwise absmax over the
# stacked (nblocks, B, B) factor, symmetric signed `dynamic` map so negative
# off-diagonal mass is representable at full range (DE has no -1.0).
FACTOR_4BIT = QuantConfig(
    bits=4, normalization="blockwise", block_size=128, mapping="dynamic", signed=True
)


def shampoo_chain(
    lr: Schedule,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
    block_size: int = 128,
    precond_every: int = 10,
    matrix_eps: float = 1e-6,
    floor_rel: float = 0.01,
    m_policy: Optional[QuantPolicy] = None,
    v_policy: Optional[QuantPolicy] = None,
    factor_policy: Optional[QuantPolicy] = None,
):
    """The bare Shampoo transformation chain (no ``Optimizer`` facade).

    ``factor_policy`` governs all four Kronecker factor trees; it is forced
    to ``min_ndim=2`` because factors only exist for matrix params (vector
    params hold empty placeholders that must stay raw).
    """
    m_policy = m_policy or QuantPolicy()
    v_policy = v_policy or QuantPolicy()
    factor_policy = dataclasses.replace(
        factor_policy or QuantPolicy(), min_ndim=max(2, (factor_policy or QuantPolicy()).min_ndim)
    )
    return chain(
        compressed(
            scale_by_shampoo(
                b1=b1,
                b2=b2,
                eps=eps,
                block_size=block_size,
                precond_every=precond_every,
                matrix_eps=matrix_eps,
                floor_rel=floor_rel,
            ),
            {
                "m": m_policy,
                "v": v_policy,
                "stats_l": factor_policy,
                "stats_r": factor_policy,
                "precond_l": factor_policy,
                "precond_r": factor_policy,
            },
        ),
        add_decayed_weights(weight_decay),
        scale_by_learning_rate(lr),
    )


def shampoo32(lr: Schedule, name: str = "shampoo32", **kw) -> Optimizer:
    """fp32 blocked Shampoo with AdamW grafting — the parity oracle."""
    return as_optimizer(shampoo_chain(lr, **kw), name=name)


def shampoo4bit(lr: Schedule, stochastic_rounding: bool = False, **kw) -> Optimizer:
    """4-bit Shampoo: 4-bit Kronecker factors + the paper's 4-bit moments."""
    m_cfg, v_cfg, f_cfg = M_4BIT, V_4BIT, FACTOR_4BIT
    if stochastic_rounding:
        m_cfg = dataclasses.replace(m_cfg, stochastic_rounding=True)
        v_cfg = dataclasses.replace(v_cfg, stochastic_rounding=True)
        f_cfg = dataclasses.replace(f_cfg, stochastic_rounding=True)
    return as_optimizer(
        shampoo_chain(
            lr,
            m_policy=QuantPolicy(config=m_cfg),
            v_policy=QuantPolicy(config=v_cfg),
            factor_policy=QuantPolicy(config=f_cfg),
            **kw,
        ),
        name="shampoo4bit",
    )
