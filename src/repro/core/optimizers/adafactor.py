"""Adafactor baseline (Shazeer & Stern 2018), as compared in the paper.

Factored second moment over the trailing two dims for ndim>=2 tensors, full
fp32 second moment for 1-d. The paper compares both the β1>0 configuration
(same β1 as AdamW) and β1=0 (no first moment, most memory-efficient). We keep
the paper's comparison protocol: AdamW hyperparameters carried over, RMS
update clipping d=1.0 from the Adafactor paper.  The update rule lives in
``transform.scale_by_factored_rms``; this module is the paper-named chain.
"""

from __future__ import annotations

from repro.core.optimizers.base import Optimizer
from repro.core.optimizers.transform import (
    Schedule,
    add_decayed_weights,
    as_optimizer,
    chain,
    scale_by_factored_rms,
    scale_by_learning_rate,
)

__all__ = ["adafactor"]


def adafactor(
    lr: Schedule,
    b1: float = 0.9,  # 0.0 disables the first moment (paper's ‡ config)
    b2: float = 0.999,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.01,
) -> Optimizer:
    tx = chain(
        scale_by_factored_rms(b1=b1, b2=b2, eps=eps, clip_threshold=clip_threshold),
        add_decayed_weights(weight_decay),
        scale_by_learning_rate(lr),
    )
    return as_optimizer(tx, name=f"adafactor(b1={b1})")
