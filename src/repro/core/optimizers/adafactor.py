"""Adafactor baseline (Shazeer & Stern 2018), as compared in the paper.

Factored second moment over the trailing two dims for ndim>=2 tensors, full
fp32 second moment for 1-d. The paper compares both the β1>0 configuration
(same β1 as AdamW) and β1=0 (no first moment, most memory-efficient). We keep
the paper's comparison protocol: AdamW hyperparameters carried over, RMS
update clipping d=1.0 from the Adafactor paper.
"""

from __future__ import annotations

from typing import Callable, Union

import jax
import jax.numpy as jnp

from repro.core.optimizers.base import FactoredMoment, Optimizer

__all__ = ["adafactor"]

Schedule = Union[float, Callable[[jnp.ndarray], jnp.ndarray]]


def adafactor(
    lr: Schedule,
    b1: float = 0.9,  # 0.0 disables the first moment (paper's ‡ config)
    b2: float = 0.999,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.01,
) -> Optimizer:
    def init(params):
        def init_v(p):
            if p.ndim >= 2:
                return FactoredMoment.zeros(p.shape)
            return jnp.zeros(p.shape, jnp.float32)

        state = {
            "v": jax.tree_util.tree_map(init_v, params),
            "step": jnp.zeros((), jnp.int32),
        }
        if b1 > 0:
            state["m"] = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
        return state

    def update(grads, state, params, key=None):
        del key
        step = state["step"] + 1
        lr_t = lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)
        bc2 = 1.0 - jnp.power(jnp.float32(b2), step.astype(jnp.float32))

        is_leaf = lambda x: isinstance(x, FactoredMoment)
        leaves_g, treedef = jax.tree_util.tree_flatten(grads)
        leaves_p = treedef.flatten_up_to(params)
        leaves_v = jax.tree_util.tree_flatten(state["v"], is_leaf=is_leaf)[0]
        leaves_m = (
            jax.tree_util.tree_flatten(state["m"])[0]
            if b1 > 0
            else [None] * len(leaves_g)
        )

        new_p, new_v, new_m = [], [], []
        for g, p, v_s, m in zip(leaves_g, leaves_p, leaves_v, leaves_m):
            g = g.astype(jnp.float32)
            sq = g * g + eps
            if isinstance(v_s, FactoredMoment):
                v2 = v_s.ema_update(sq, b2)
                v_hat = v2.reconstruct() / bc2
            else:
                v2 = b2 * v_s + (1 - b2) * sq
                v_hat = v2 / bc2
            u = g / jnp.sqrt(jnp.maximum(v_hat, eps))
            # Adafactor update clipping: divide by max(1, RMS(u)/d).
            rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            if m is not None:
                m2 = b1 * m + (1 - b1) * u
                new_m.append(m2)
                u = m2
            p2 = (p.astype(jnp.float32) - lr_t * (u + weight_decay * p)).astype(
                p.dtype
            )
            new_p.append(p2)
            new_v.append(v2)

        out_state = {
            "v": jax.tree_util.tree_unflatten(treedef, new_v),
            "step": step,
        }
        if b1 > 0:
            out_state["m"] = jax.tree_util.tree_unflatten(treedef, new_m)
        return jax.tree_util.tree_unflatten(treedef, new_p), out_state

    return Optimizer(init=init, update=update, name=f"adafactor(b1={b1})")
