"""Optimizer interface + the compression framework of Alg. 1.

An ``Optimizer`` is an (init, update) pair over parameter pytrees:

    state              = opt.init(params)
    params, state      = opt.update(grads, state, params)

State moments may be stored compressed (``QuantizedTensor``), factored
(``FactoredMoment``), or raw fp32 — decided per-leaf at init time by a
``QuantPolicy`` implementing the paper's App. D.1 rules (size threshold 4096,
optional path exclusions such as embeddings for the 8-bit baseline).

The compress/decompress of Alg. 1 lives in ``compress_moment`` /
``decompress_moment``: line 3 (decompress), lines 4 (inner optimizer A) and 5
(compress) are what each concrete optimizer's ``update`` composes per leaf.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.quantizer import QuantConfig, QuantizedTensor, dequantize, quantize

__all__ = [
    "Optimizer",
    "QuantPolicy",
    "FactoredMoment",
    "compress_moment",
    "decompress_moment",
    "tree_paths",
    "state_nbytes",
]

PyTree = Any


class Optimizer(NamedTuple):
    """A gradient-based optimizer as an (init, update) pair (paper's A)."""

    init: Callable[[PyTree], PyTree]
    update: Callable[..., Tuple[PyTree, PyTree]]
    name: str = "optimizer"


@jax.tree_util.register_pytree_with_keys_class
class FactoredMoment:
    """Adafactor-style factored second moment over the trailing two dims.

    For a tensor of shape (..., n, m): ``row`` has shape (..., n) (mean over
    m) and ``col`` has shape (..., m) (mean over n). The reconstruction is
    row ⊗ col / mean(row) (Shazeer & Stern, 2018).
    """

    def __init__(self, row: jnp.ndarray, col: jnp.ndarray, shape: Tuple[int, ...]):
        self.row = row
        self.col = col
        self.shape = tuple(shape)

    def tree_flatten_with_keys(self):
        k = jax.tree_util.GetAttrKey
        return ((k("row"), self.row), (k("col"), self.col)), (self.shape,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        row, col = children
        return cls(row, col, aux[0])

    @staticmethod
    def zeros(shape: Tuple[int, ...]) -> "FactoredMoment":
        return FactoredMoment(
            jnp.zeros(shape[:-1], jnp.float32),
            jnp.zeros(shape[:-2] + shape[-1:], jnp.float32),
            shape,
        )

    def reconstruct(self) -> jnp.ndarray:
        """v̂ = row ⊗ col / mean(row); guard all-zero rows at t=0."""
        denom = jnp.maximum(jnp.mean(self.row, axis=-1, keepdims=True), 1e-30)
        return (self.row / denom)[..., :, None] * self.col[..., None, :]

    def ema_update(self, sq: jnp.ndarray, b2: float) -> "FactoredMoment":
        row = b2 * self.row + (1 - b2) * jnp.mean(sq, axis=-1)
        col = b2 * self.col + (1 - b2) * jnp.mean(sq, axis=-2)
        return FactoredMoment(row, col, self.shape)

    def nbytes(self) -> int:
        return int(self.row.size * 4 + self.col.size * 4)

    def __repr__(self) -> str:  # pragma: no cover
        return f"FactoredMoment(shape={self.shape})"


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """Per-leaf compression decision (paper App. D.1).

    - leaves with <= ``threshold`` elements stay fp32
    - leaves whose path matches any ``exclude`` regex stay fp32
      (used by the 8-bit baseline to skip embeddings)
    - leaves with fewer than ``min_ndim`` dims stay fp32 (matrix-factor
      state — Shampoo Kronecker blocks — only exists for matrix params;
      their vector/scalar siblings hold empty placeholders that must not
      be quantized)
    - second moment may additionally be *factored* for ndim >= 2
      (the 4-bit Factor optimizer).
    """

    config: Optional[QuantConfig] = None
    threshold: int = 4096
    exclude: Tuple[str, ...] = ()
    factor_2d: bool = False  # second-moment factorization for ndim >= 2
    min_ndim: int = 0  # param rank below which the state leaf stays raw

    def mode(self, path: str, shape: Tuple[int, ...]) -> str:
        """-> 'raw' | 'quant' | 'factor'."""
        size = 1
        for d in shape:
            size *= d
        if self.config is None and not self.factor_2d:
            return "raw"
        if size <= self.threshold:
            return "raw"
        if len(shape) < self.min_ndim:
            return "raw"
        for pat in self.exclude:
            if re.search(pat, path):
                return "raw"
        if self.factor_2d and len(shape) >= 2:
            return "factor"
        if self.config is None:
            return "raw"
        return "quant"


def tree_paths(tree: PyTree) -> PyTree:
    """Pytree of '/'-joined string paths, same structure as ``tree``."""

    def _name(entry) -> str:
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
        if isinstance(entry, jax.tree_util.SequenceKey):
            return str(entry.idx)
        if isinstance(entry, jax.tree_util.GetAttrKey):
            return entry.name
        return str(entry)

    paths_leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    paths = ["/".join(_name(k) for k in path) for path, _ in paths_leaves]
    treedef = jax.tree_util.tree_structure(tree)
    return jax.tree_util.tree_unflatten(treedef, paths)


def compress_moment(
    x: jnp.ndarray,
    mode: str,
    config: Optional[QuantConfig],
    key: Optional[jax.Array] = None,
):
    """Alg. 1 line 5 for one leaf."""
    if mode == "quant":
        return quantize(x, config, key=key)
    return x.astype(jnp.float32)


def decompress_moment(s) -> jnp.ndarray:
    """Alg. 1 line 3 for one leaf."""
    if isinstance(s, QuantizedTensor):
        return dequantize(s)
    if isinstance(s, FactoredMoment):
        return s.reconstruct()
    return s


def state_nbytes(state: PyTree) -> int:
    """Persistent bytes of an optimizer state pytree (Tab. 4/5 accounting)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(
        state, is_leaf=lambda x: isinstance(x, (QuantizedTensor, FactoredMoment))
    ):
        if isinstance(leaf, (QuantizedTensor, FactoredMoment)):
            total += leaf.nbytes()
        else:
            total += int(leaf.size * leaf.dtype.itemsize)
    return total
