"""Composable gradient-transformation API (optax-style) with one shared
compressed-state wrapper implementing the paper's Alg. 1.

Why
---
Every optimizer in the paper's zoo used to re-implement its own pytree
flatten / per-leaf decompress->step->compress loop.  This module factors the
optimizer layer into orthogonal pieces so the Alg. 1 compression machinery
exists exactly once:

* ``GradientTransformation`` — an ``(init, update)`` pair over *updates*
  (gradients flowing through the chain), not parameters.  ``update`` has the
  signature ``update(updates, state, params=None, *, key=None)`` and returns
  ``(new_updates, new_state)``.
* pure update rules — ``scale_by_adam`` (Eq. 1), ``trace`` (Alg. 2 SGDM
  accumulator), ``scale_by_sm3``, ``scale_by_factored_rms`` (Adafactor),
  ``add_decayed_weights``, ``scale_by_learning_rate`` (schedule-aware).
* ``compressed(inner, policies)`` — THE Alg. 1 wrapper.  It owns per-leaf
  ``QuantPolicy`` resolution (paper App. D.1), decompress (line 3) before the
  inner rule runs, compress (line 5) after, the stochastic-rounding PRNG-key
  plumbing, and routing of eligible leaves through the fused Pallas kernel
  (``FusedAdamWRoute``).  Inner transforms only ever see fp32 moments (or a
  ``FactoredMoment``, which they update structurally).
* ``chain(*transforms)`` — composes transforms left to right.
* ``partition(transforms, labels)`` — optax.multi_transform-style routing of
  parameter subtrees to different chains (e.g. fp32 embeddings + 4-bit body),
  subsuming the regex ``exclude`` mechanism for new configurations.
* ``as_optimizer(tx)`` — adapts a chain to the repo-wide ``Optimizer``
  facade: ``params2 = params + final_updates`` (with ``Replace`` leaves from
  the fused kernel applied verbatim).

How ``compressed`` maps to Alg. 1
---------------------------------
For each parameter leaf ``p`` with gradient ``g`` and compressed state
``s̄``::

    line 3:  s  = decompress(s̄)            # compressed() before inner.update
    line 4:  s' = A(g, s, p)               # the wrapped inner transform
    line 5:  s̄' = compress(s')             # compressed() after inner.update

``policies`` maps *inner-state field names* (e.g. ``{"m": ..., "v": ...}``)
to ``QuantPolicy``.  Per leaf, the policy resolves to 'raw' (fp32), 'quant'
(``QuantizedTensor``) or 'factor' (``FactoredMoment``, for rules that
understand it, e.g. the second moment of ``scale_by_adam``).

Migration notes (pre-chain ``quantized_adamw`` callers)
-------------------------------------------------------
* Constructors (``adamw32/8bit/4bit``, ``factor4bit``, ``sgdm{,4bit}``,
  ``sm3``, ``adafactor``) keep their exact signatures and produce
  bit-identical trajectories (tests/test_transforms.py); only the *state
  pytree layout* changed: it is now a ``ChainState`` of per-transform states,
  so old checkpoints must be re-created.
* ``state["m"] / state["v"] / state["trace"]`` still work: ``ChainState``
  resolves string keys by searching the nested transform states, so code
  that inspects moments (tests, memory accounting) needs no change.  SGDM's
  momentum field is named ``trace`` (was ``"m"``).
* ``opt.update(grads, state, params, key=...)`` is unchanged at the
  ``Optimizer`` facade; the key now threads through ``compressed()``.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, Dict, Mapping, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.optimizers.base import (
    FactoredMoment,
    Optimizer,
    QuantPolicy,
    compress_moment,
    decompress_moment,
    tree_paths,
)
from repro.core.quantizer import QuantizedTensor, quantize

__all__ = [
    "GradientTransformation",
    "ChainState",
    "EmptyState",
    "Replace",
    "chain",
    "compressed",
    "partition",
    "PartitionState",
    "MaskedNode",
    "label_by_regex",
    "as_optimizer",
    "apply_updates",
    "scale_by_adam",
    "trace",
    "scale_by_sm3",
    "scale_by_factored_rms",
    "scale_by_shampoo",
    "add_decayed_weights",
    "scale_by_learning_rate",
    "FusedAdamWRoute",
]

PyTree = Any
Schedule = Union[float, Callable[[jnp.ndarray], jnp.ndarray]]


class GradientTransformation(NamedTuple):
    """An (init, update) pair over *updates* (optax-style).

    ``init(params) -> state``;
    ``update(updates, state, params=None, *, key=None) -> (updates, state)``.
    """

    init: Callable[[PyTree], PyTree]
    update: Callable[..., Tuple[PyTree, PyTree]]


class EmptyState(NamedTuple):
    """State of a stateless transform."""


def _resolve_lr(lr: Schedule, step: jnp.ndarray) -> jnp.ndarray:
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


# ---------------------------------------------------------------------------
# update-tree plumbing: Replace leaves + leaf-wise maps that respect them
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
class Replace:
    """An update leaf carrying the *new parameter value* verbatim.

    Emitted by fused whole-step paths (the Pallas kernel computes
    ``w_new`` in-kernel, including lr/weight-decay).  Downstream transforms
    pass it through untouched and ``apply_updates`` installs it as-is, so the
    fused result is bit-identical regardless of what else is in the chain.
    """

    def __init__(self, value):
        self.value = value

    def tree_flatten(self):
        return (self.value,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0])

    def __repr__(self) -> str:  # pragma: no cover
        return f"Replace({self.value!r})"


_IS_UPDATE_LEAF = lambda x: isinstance(x, Replace)


def tree_map_updates(f, updates: PyTree, *rest: PyTree) -> PyTree:
    """tree_map over update leaves that passes ``Replace`` leaves through."""
    leaves, treedef = jax.tree_util.tree_flatten(updates, is_leaf=_IS_UPDATE_LEAF)
    rest_leaves = [treedef.flatten_up_to(r) for r in rest]
    out = [
        u if isinstance(u, Replace) else f(u, *(rl[i] for rl in rest_leaves))
        for i, u in enumerate(leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, out)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    """``p' = (p_f32 + u).astype(p.dtype)``; ``Replace`` leaves verbatim."""
    leaves_u, treedef = jax.tree_util.tree_flatten(updates, is_leaf=_IS_UPDATE_LEAF)
    leaves_p = treedef.flatten_up_to(params)
    out = [
        u.value
        if isinstance(u, Replace)
        else (p.astype(jnp.float32) + u).astype(p.dtype)
        for p, u in zip(leaves_p, leaves_u)
    ]
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# chain
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_with_keys_class
class ChainState:
    """Tuple of per-transform states with a migration-friendly ``[]``.

    ``state[i]`` is the i-th transform's state; ``state["m"]`` searches the
    nested states for a field of that name (so pre-refactor code reading
    ``state["m"]["w"].codes`` keeps working on chain-built optimizers).
    """

    __slots__ = ("states",)

    def __init__(self, states):
        self.states = tuple(states)

    def tree_flatten_with_keys(self):
        # keyed flattening => checkpoint manifests record readable paths
        # (".states[2].inner.m['embed'].codes") instead of flat indices.
        return ((jax.tree_util.GetAttrKey("states"), self.states),), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0])

    def __getitem__(self, key):
        if isinstance(key, (int, slice)):
            return self.states[key]
        found = _find_state_field(self.states, key)
        if found is _NOT_FOUND:
            raise KeyError(key)
        return found

    def __len__(self) -> int:
        return len(self.states)

    def __repr__(self) -> str:  # pragma: no cover
        return f"ChainState({list(self.states)!r})"


_NOT_FOUND = object()


def _find_state_field(node, name: str):
    """DFS for a NamedTuple field (or dict key) called ``name``."""
    if isinstance(node, ChainState):
        node = node.states
    if isinstance(node, tuple) and hasattr(node, "_fields"):
        if name in node._fields and getattr(node, name) is not None:
            # None fields are absent moments (e.g. adafactor b1=0 has no m);
            # keep searching so the lookup raises KeyError like the old dicts.
            return getattr(node, name)
        children = tuple(node)
    elif isinstance(node, dict):
        if name in node:
            return node[name]
        children = tuple(node.values())
    elif isinstance(node, (tuple, list)):
        children = tuple(node)
    else:
        return _NOT_FOUND
    for child in children:
        found = _find_state_field(child, name)
        if found is not _NOT_FOUND:
            return found
    return _NOT_FOUND


def chain(*transforms: GradientTransformation) -> GradientTransformation:
    """Compose transforms; updates flow left to right through each."""

    def init(params):
        return ChainState(tx.init(params) for tx in transforms)

    def update(updates, state, params=None, *, key=None):
        new_states = []
        for tx, s in zip(transforms, state.states):
            updates, s2 = tx.update(updates, s, params, key=key)
            new_states.append(s2)
        return updates, ChainState(new_states)

    return GradientTransformation(init, update)


def as_optimizer(tx: GradientTransformation, name: str = "optimizer") -> Optimizer:
    """Adapt a transformation chain to the (init, update)->params facade."""

    def init(params):
        return tx.init(params)

    def update(grads, state, params, key: Optional[jax.Array] = None):
        updates, new_state = tx.update(grads, state, params, key=key)
        return apply_updates(params, updates), new_state

    return Optimizer(init=init, update=update, name=name)


# ---------------------------------------------------------------------------
# pure update rules
# ---------------------------------------------------------------------------


class ScaleByAdamState(NamedTuple):
    count: jnp.ndarray
    m: PyTree
    v: PyTree


def scale_by_adam(
    b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8
) -> GradientTransformation:
    """Bias-corrected Adam direction (paper Eq. 1): ``m̂ / (sqrt(v̂)+eps)``.

    A second-moment leaf may be a ``FactoredMoment`` (installed by
    ``compressed`` under a ``factor_2d`` policy): it is updated structurally
    via its row/col EMA and reconstructed for the denominator.
    """

    def init(params):
        zeros = lambda: jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        return ScaleByAdamState(jnp.zeros((), jnp.int32), zeros(), zeros())

    def update(updates, state, params=None, *, key=None):
        del params, key
        count = state.count + 1
        bc1 = 1.0 - jnp.power(jnp.float32(b1), count.astype(jnp.float32))
        bc2 = 1.0 - jnp.power(jnp.float32(b2), count.astype(jnp.float32))

        leaves_g, treedef = jax.tree_util.tree_flatten(updates)
        leaves_m = treedef.flatten_up_to(state.m)
        leaves_v = treedef.flatten_up_to(state.v)

        out, new_m, new_v = [], [], []
        for g, m, v in zip(leaves_g, leaves_m, leaves_v):
            g = g.astype(jnp.float32)
            m2 = b1 * m + (1.0 - b1) * g
            if isinstance(v, FactoredMoment):
                v2 = v.ema_update(g * g, b2)
                v_full = v2.reconstruct()
            else:
                v2 = b2 * v + (1.0 - b2) * g * g
                v_full = v2
            m_hat = m2 / bc1
            v_hat = v_full / bc2
            out.append(m_hat / (jnp.sqrt(v_hat) + eps))
            new_m.append(m2)
            new_v.append(v2)

        unf = lambda ls: jax.tree_util.tree_unflatten(treedef, ls)
        return unf(out), ScaleByAdamState(count, unf(new_m), unf(new_v))

    return GradientTransformation(init, update)


class TraceState(NamedTuple):
    trace: PyTree


def trace(decay: float) -> GradientTransformation:
    """SGDM accumulator (paper Alg. 2 line 4): ``t = decay*t + g`` (no
    ``(1-decay)`` damping — the convention Theorem 1's constants assume)."""

    def init(params):
        return TraceState(
            jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        )

    def update(updates, state, params=None, *, key=None):
        del params, key
        leaves_g, treedef = jax.tree_util.tree_flatten(updates)
        leaves_t = treedef.flatten_up_to(state.trace)
        new_t = [decay * t + g.astype(jnp.float32) for g, t in zip(leaves_g, leaves_t)]
        tree = jax.tree_util.tree_unflatten(treedef, new_t)
        return tree, TraceState(tree)

    return GradientTransformation(init, update)


class Sm3State(NamedTuple):
    acc: PyTree
    m: PyTree


def _broadcast_min(accs, shape):
    """nu_ij = min_r acc_r[i_r] broadcast to ``shape`` (SM3 Alg. 4 style)."""
    out = None
    for r, acc in enumerate(accs):
        view = [1] * len(shape)
        view[r] = shape[r]
        b = acc.reshape(view)
        out = b if out is None else jnp.minimum(out, b)
    return jnp.broadcast_to(out, shape)


def scale_by_sm3(b1: float = 0.9, eps: float = 1e-8) -> GradientTransformation:
    """SM3 (Anil et al. 2019): sublinear accumulators (one vector per tensor
    dim) + the β1>0 momentum variant the paper compares against."""

    def init(params):
        def init_acc(p):
            if p.ndim == 0:
                return (jnp.zeros((1,), jnp.float32),)
            return tuple(jnp.zeros((d,), jnp.float32) for d in p.shape)

        return Sm3State(
            acc=jax.tree_util.tree_map(
                init_acc, params, is_leaf=lambda x: hasattr(x, "shape")
            ),
            m=jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            ),
        )

    def update(updates, state, params=None, *, key=None):
        del params, key
        leaves_g, treedef = jax.tree_util.tree_flatten(updates)
        leaves_acc = treedef.flatten_up_to(state.acc)
        leaves_m = treedef.flatten_up_to(state.m)

        out, new_acc, new_m = [], [], []
        for g, accs, m in zip(leaves_g, leaves_acc, leaves_m):
            g = g.astype(jnp.float32)
            shape = g.shape if g.ndim > 0 else (1,)
            g_ = g.reshape(shape)
            nu = _broadcast_min(accs, shape) + g_ * g_
            accs2 = tuple(
                jnp.max(nu, axis=tuple(i for i in range(len(shape)) if i != r))
                for r in range(len(shape))
            )
            u = (g_ / (jnp.sqrt(nu) + eps)).reshape(g.shape)
            m2 = b1 * m + (1 - b1) * u
            out.append(m2)
            new_acc.append(accs2)
            new_m.append(m2)

        unf = lambda ls: jax.tree_util.tree_unflatten(treedef, ls)
        return unf(out), Sm3State(unf(new_acc), unf(new_m))

    return GradientTransformation(init, update)


class FactoredRmsState(NamedTuple):
    count: jnp.ndarray
    v: PyTree
    m: Optional[PyTree]


def scale_by_factored_rms(
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
) -> GradientTransformation:
    """Adafactor (Shazeer & Stern 2018): factored second moment for ndim>=2,
    RMS update clipping, optional first moment (``b1 == 0`` disables it)."""

    def init(params):
        v = jax.tree_util.tree_map(
            lambda p: FactoredMoment.zeros(p.shape)
            if p.ndim >= 2
            else jnp.zeros(p.shape, jnp.float32),
            params,
        )
        m = None
        if b1 > 0:
            m = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
        return FactoredRmsState(jnp.zeros((), jnp.int32), v, m)

    def update(updates, state, params=None, *, key=None):
        del params, key
        count = state.count + 1
        bc2 = 1.0 - jnp.power(jnp.float32(b2), count.astype(jnp.float32))

        leaves_g, treedef = jax.tree_util.tree_flatten(updates)
        leaves_v = treedef.flatten_up_to(state.v)
        leaves_m = (
            treedef.flatten_up_to(state.m)
            if state.m is not None
            else [None] * len(leaves_g)
        )

        out, new_v, new_m = [], [], []
        for g, v, m in zip(leaves_g, leaves_v, leaves_m):
            g = g.astype(jnp.float32)
            sq = g * g + eps
            if isinstance(v, FactoredMoment):
                v2 = v.ema_update(sq, b2)
                v_hat = v2.reconstruct() / bc2
            else:
                v2 = b2 * v + (1 - b2) * sq
                v_hat = v2 / bc2
            u = g / jnp.sqrt(jnp.maximum(v_hat, eps))
            # Adafactor update clipping: divide by max(1, RMS(u)/d).
            rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            if m is not None:
                m2 = b1 * m + (1 - b1) * u
                new_m.append(m2)
                u = m2
            out.append(u)
            new_v.append(v2)

        unf = lambda ls: jax.tree_util.tree_unflatten(treedef, ls)
        return unf(out), FactoredRmsState(
            count, unf(new_v), unf(new_m) if state.m is not None else None
        )

    return GradientTransformation(init, update)


class ScaleByShampooState(NamedTuple):
    count: jnp.ndarray
    m: PyTree  # grafting first moment (Adam m)
    v: PyTree  # grafting second moment (Adam v)
    stats_l: PyTree  # (nblocks, Br, Br) left Kronecker statistics L += G Gᵀ
    stats_r: PyTree  # (nblocks, Bc, Bc) right Kronecker statistics R += Gᵀ G
    precond_l: PyTree  # (nblocks, Br, Br) L^{-1/4}
    precond_r: PyTree  # (nblocks, Bc, Bc) R^{-1/4}


def _shampoo_geometry(shape: Tuple[int, ...], block_size: int):
    """Static blocking of a >=2-d param: leading dims merge into rows, the
    trailing dim is columns; each dim tiles at min(block_size, dim)."""
    n = 1
    for d in shape[:-1]:
        n *= int(d)
    m = int(shape[-1])
    br = min(block_size, n)
    bc = min(block_size, m)
    nb_r = -(-n // br)
    nb_c = -(-m // bc)
    return n, m, br, bc, nb_r, nb_c


def _shampoo_to_blocks(x2d, n, m, br, bc, nb_r, nb_c):
    x = jnp.pad(x2d, ((0, nb_r * br - n), (0, nb_c * bc - m)))
    x = x.reshape(nb_r, br, nb_c, bc).transpose(0, 2, 1, 3)
    return x.reshape(nb_r * nb_c, br, bc)


def _shampoo_from_blocks(bx, n, m, br, bc, nb_r, nb_c):
    x = bx.reshape(nb_r, nb_c, br, bc).transpose(0, 2, 1, 3)
    return x.reshape(nb_r * br, nb_c * bc)[:n, :m]


def _shampoo_pad_diag(n, m, br, bc, nb_r, nb_c):
    """Per-block diagonal indicators of PADDED rows/cols (static fp32 masks).

    Padded dims get +1.0 on the statistics diagonal before the inverse root
    so their eigenvalues sit at ~1.0 (inert: the root maps them to ~1.0)
    instead of at the ridge eps, whose eps^{-1/4} would both poison the
    blockwise absmax scales of quantized preconditioner factors and be
    multiplied only by zero-padded gradient entries anyway.
    """
    rows = np.arange(nb_r * br).reshape(nb_r, br) >= n
    cols = np.arange(nb_c * bc).reshape(nb_c, bc) >= m
    pad_l = np.repeat(rows, nb_c, axis=0).astype(np.float32)  # (nb, br)
    pad_r = np.tile(cols, (nb_r, 1)).astype(np.float32)  # (nb, bc)
    return jnp.asarray(pad_l), jnp.asarray(pad_r)


def _inv_quarter_root(stats, pad_diag, ridge, floor_rel):
    """(stats + ridge*I + diag(pad))^{-1/4} per block, via batched eigh.

    Eigenvalues are floored at ``max(ridge, floor_rel * λ_max)`` per block.
    The RELATIVE floor is load-bearing for quantized factors: 4-bit
    requantization noise on the statistics manufactures spurious near-zero
    (even negative) eigenvalues, and an absolute floor lets their ^{-1/4}
    amplification (~ridge^{-1/4}) dominate the direction with pure noise as
    gradients shrink.  Flooring relative to the block's top eigenvalue caps
    the amplification ratio at ``floor_rel^{-1/4}`` no matter the scale —
    the same trick production Shampoo implementations use for their
    ``matrix_epsilon``.
    """
    d = stats.shape[-1]
    eye = jnp.eye(d, dtype=jnp.float32)
    a = stats + ridge * eye + pad_diag[:, :, None] * eye
    w, u = jnp.linalg.eigh(a)
    wmax = jnp.max(w, axis=-1, keepdims=True)
    w = jnp.maximum(w, jnp.maximum(ridge, floor_rel * wmax))
    return jnp.einsum("kij,kj,klj->kil", u, w**-0.25, u)


def scale_by_shampoo(
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    *,
    block_size: int = 128,
    precond_every: int = 10,
    matrix_eps: float = 1e-6,
    floor_rel: float = 0.01,
) -> GradientTransformation:
    """Blocked Shampoo (Gupta et al. 2018, the block-diagonal variant of
    Anil et al. 2020) with AdamW-shaped grafting, as a pure rule.

    Each >=2-d param is matricized (leading dims -> rows) and tiled into
    blocks of at most ``block_size`` per side.  Per block::

        L <- b2 L + (1-b2) G Gᵀ        R <- b2 R + (1-b2) Gᵀ G
        every precond_every steps:  P_L = L̂^{-1/4},  P_R = R̂^{-1/4}   (eigh)
        direction  D = P_L m̂ P_R       (m̂ = bias-corrected momentum)

    The emitted update grafts D onto the AdamW direction's norm
    (``D * ||adam_dir|| / ||D||`` per leaf), so the step SIZE schedule is
    exactly AdamW's while the step DIRECTION is second-order — the standard
    trick that lets Shampoo reuse first-order lr tuning, and what makes the
    downstream chain (weight decay + lr) AdamW-shaped.  Params with ndim < 2
    fall back to the AdamW direction and hold empty ``(0,)`` factor
    placeholders.

    All four factor trees (``stats_l/stats_r/precond_l/precond_r``) mirror
    the param tree one array per leaf, so ``compressed()`` can hold them as
    4-bit ``QuantizedTensor``s like any first-order moment (*4-bit Shampoo*).
    Inverse roots are recomputed every ``precond_every`` steps under
    ``lax.cond``; between recomputes the stale ``P`` is reused.
    ``floor_rel`` floors each block's eigenvalues relative to its largest
    before the inverse root — see ``_inv_quarter_root`` for why this is
    essential once the factors are quantized.
    """

    def _placeholder():
        return jnp.zeros((0,), jnp.float32)

    def init(params):
        zeros = lambda: jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )

        def factor(p, side, identity):
            if p.ndim < 2:
                return _placeholder()
            n, m, br, bc, nb_r, nb_c = _shampoo_geometry(p.shape, block_size)
            d = br if side == "l" else bc
            nb = nb_r * nb_c
            base = jnp.zeros((nb, d, d), jnp.float32)
            return base + jnp.eye(d, dtype=jnp.float32) if identity else base

        f = lambda side, identity: jax.tree_util.tree_map(
            lambda p: factor(p, side, identity), params
        )
        return ScaleByShampooState(
            jnp.zeros((), jnp.int32),
            zeros(),
            zeros(),
            f("l", False),
            f("r", False),
            f("l", True),
            f("r", True),
        )

    def update(updates, state, params=None, *, key=None):
        del params, key
        count = state.count + 1
        cf = count.astype(jnp.float32)
        bc1 = 1.0 - jnp.power(jnp.float32(b1), cf)
        bc2 = 1.0 - jnp.power(jnp.float32(b2), cf)
        # Recompute on step 1 (so the first update is already preconditioned
        # by the first gradient's statistics) and every precond_every after.
        recompute = ((count - 1) % precond_every) == 0

        leaves_g, treedef = jax.tree_util.tree_flatten(updates)
        fields = {
            name: treedef.flatten_up_to(getattr(state, name))
            for name in ("m", "v", "stats_l", "stats_r", "precond_l", "precond_r")
        }

        out = []
        new = {name: [] for name in fields}
        for i, g in enumerate(leaves_g):
            g = g.astype(jnp.float32)
            m, v = fields["m"][i], fields["v"][i]
            sl, sr = fields["stats_l"][i], fields["stats_r"][i]
            pl, pr = fields["precond_l"][i], fields["precond_r"][i]

            m2 = b1 * m + (1.0 - b1) * g
            v2 = b2 * v + (1.0 - b2) * g * g
            adam_dir = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)
            new["m"].append(m2)
            new["v"].append(v2)

            if g.ndim < 2:
                out.append(adam_dir)
                for name in ("stats_l", "stats_r", "precond_l", "precond_r"):
                    new[name].append(fields[name][i])
                continue

            geo = _shampoo_geometry(g.shape, block_size)
            n, mm = geo[0], geo[1]
            pad_l, pad_r = _shampoo_pad_diag(*geo)
            gb = _shampoo_to_blocks(g.reshape(n, mm), *geo)
            sl2 = b2 * sl + (1.0 - b2) * jnp.einsum("kij,klj->kil", gb, gb)
            sr2 = b2 * sr + (1.0 - b2) * jnp.einsum("kji,kjl->kil", gb, gb)
            pl2 = jax.lax.cond(
                recompute,
                lambda s, old: _inv_quarter_root(s / bc2, pad_l, matrix_eps, floor_rel),
                lambda s, old: old,
                sl2,
                pl,
            )
            pr2 = jax.lax.cond(
                recompute,
                lambda s, old: _inv_quarter_root(s / bc2, pad_r, matrix_eps, floor_rel),
                lambda s, old: old,
                sr2,
                pr,
            )
            mb = _shampoo_to_blocks((m2 / bc1).reshape(n, mm), *geo)
            db = jnp.einsum("kij,kjl,klo->kio", pl2, mb, pr2)
            d = _shampoo_from_blocks(db, *geo).reshape(g.shape)
            a_norm = jnp.sqrt(jnp.sum(adam_dir * adam_dir))
            d_norm = jnp.sqrt(jnp.sum(d * d))
            out.append(d * (a_norm / (d_norm + 1e-30)))
            new["stats_l"].append(sl2)
            new["stats_r"].append(sr2)
            new["precond_l"].append(pl2)
            new["precond_r"].append(pr2)

        unf = lambda ls: jax.tree_util.tree_unflatten(treedef, ls)
        return unf(out), ScaleByShampooState(
            count, *(unf(new[name]) for name in ScaleByShampooState._fields[1:])
        )

    return GradientTransformation(init, update)


def add_decayed_weights(weight_decay: float) -> GradientTransformation:
    """Decoupled weight decay: ``u <- u + weight_decay * p`` (AdamW-style)."""

    def init(params):
        del params
        return EmptyState()

    def update(updates, state, params=None, *, key=None):
        del key
        return (
            tree_map_updates(lambda u, p: u + weight_decay * p, updates, params),
            state,
        )

    return GradientTransformation(init, update)


class ScaleByScheduleState(NamedTuple):
    count: jnp.ndarray


def scale_by_learning_rate(
    lr: Schedule, flip_sign: bool = True
) -> GradientTransformation:
    """Multiply updates by ``-lr(step)`` (descent; ``flip_sign=False`` for
    the raw schedule value).  Keeps its own step count."""

    def init(params):
        del params
        return ScaleByScheduleState(jnp.zeros((), jnp.int32))

    def update(updates, state, params=None, *, key=None):
        del params, key
        count = state.count + 1
        lr_t = _resolve_lr(lr, count)
        mult = -lr_t if flip_sign else lr_t
        return (
            tree_map_updates(lambda u: u * mult, updates),
            ScaleByScheduleState(count),
        )

    return GradientTransformation(init, update)


# ---------------------------------------------------------------------------
# compressed(): the one Alg. 1 wrapper
# ---------------------------------------------------------------------------


class CompressedState(NamedTuple):
    count: jnp.ndarray  # drives bias correction on the fused-kernel path
    inner: Any  # inner state with policy-managed moment trees held compressed


@dataclasses.dataclass(frozen=True)
class FusedAdamWRoute:
    """Routes eligible (p, g, m̄, v̄) leaves through the fused Pallas kernel.

    The kernel computes the *whole* AdamW step (dequant -> Eq. 1 -> requant
    -> param write) in one pass, so the route needs the full hyperparameters
    and emits a ``Replace`` update leaf.  Eligibility mirrors the kernel's
    layout contract: 4-bit B128 m, 4-bit rank-1 v, ndim>=2 param with the
    last dim a multiple of 256 (nibble + B128 tile alignment); leading dims
    run as stacked 2-d slices of ONE 3-d-grid launch (the outer grid dim
    walks the slices — a deep layer stack costs a single ``pallas_call``,
    not L of them).  Stochastic-rounding configs are eligible — the kernel
    requantizes with in-tile counter-based Threefry noise keyed by the
    per-leaf SR key, expanded to per-slice seed rows by one vmapped
    ``fold_in`` in ``ops.fused_adamw4_leaf`` (both moments must agree on SR
    so one key derivation covers the leaf).
    """

    lr: Schedule
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    m_field: str = "m"
    v_field: str = "v"

    def eligible(self, comp: Mapping[str, Any], p: jnp.ndarray) -> bool:
        m_s = comp.get(self.m_field)
        v_s = comp.get(self.v_field)
        return (
            isinstance(m_s, QuantizedTensor)
            and m_s.config.bits == 4
            and m_s.config.normalization == "blockwise"
            and m_s.config.block_size == 128
            and isinstance(v_s, QuantizedTensor)
            and v_s.config.bits == 4
            and v_s.config.normalization == "rank1"
            and m_s.config.stochastic_rounding == v_s.config.stochastic_rounding
            and p.ndim >= 2
            and p.shape[-1] % 256 == 0
        )

    def run(
        self,
        p: jnp.ndarray,
        g: jnp.ndarray,
        comp: Mapping[str, Any],
        step: jnp.ndarray,
        key: Optional[jax.Array] = None,
    ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
        from repro.kernels import ops as kernel_ops

        lr_t = _resolve_lr(self.lr, step)
        bc1 = 1.0 - jnp.power(jnp.float32(self.b1), step.astype(jnp.float32))
        bc2 = 1.0 - jnp.power(jnp.float32(self.b2), step.astype(jnp.float32))
        w_new, m2, v2 = kernel_ops.fused_adamw4_leaf(
            p, g, comp[self.m_field], comp[self.v_field],
            lr_t, self.b1, self.b2, self.eps, self.weight_decay, bc1, bc2,
            key=key,
        )
        return w_new, {self.m_field: m2, self.v_field: v2}


def compressed(
    inner: GradientTransformation,
    policies: Mapping[str, QuantPolicy],
    *,
    kernel: Optional[FusedAdamWRoute] = None,
) -> GradientTransformation:
    """Wrap ``inner`` so the state trees named by ``policies`` persist
    compressed (Alg. 1).  See the module docstring for the line-by-line
    mapping.  ``kernel`` optionally routes eligible leaves through the fused
    Pallas whole-step path, emitting ``Replace`` update leaves.
    """
    policies = dict(policies)
    field_names = tuple(policies)

    def _leaf_modes(params):
        leaves_p, treedef = jax.tree_util.tree_flatten(params)
        paths = jax.tree_util.tree_leaves(tree_paths(params))
        modes = {
            name: [pol.mode(path, tuple(p.shape)) for path, p in zip(paths, leaves_p)]
            for name, pol in policies.items()
        }
        return leaves_p, treedef, modes

    def init(params):
        leaves_p, treedef, modes = _leaf_modes(params)
        inner_state = inner.init(params)
        replacements = {}
        for name, pol in policies.items():
            s_leaves = treedef.flatten_up_to(getattr(inner_state, name))
            comp = []
            for p, s, mode in zip(leaves_p, s_leaves, modes[name]):
                if mode == "factor":
                    comp.append(FactoredMoment.zeros(tuple(p.shape)))
                else:
                    comp.append(compress_moment(s, mode, pol.config))
            replacements[name] = jax.tree_util.tree_unflatten(treedef, comp)
        return CompressedState(
            jnp.zeros((), jnp.int32), inner_state._replace(**replacements)
        )

    def update(updates, state, params=None, *, key=None):
        count = state.count + 1
        leaves_g, treedef = jax.tree_util.tree_flatten(updates)
        leaves_p = treedef.flatten_up_to(params)
        n = len(leaves_g)

        comp_leaves = {
            name: treedef.flatten_up_to(getattr(state.inner, name))
            for name in field_names
        }

        # Alg. 1 line 3: hand the inner rule fp32 views of quantized moments
        # (FactoredMoment and raw leaves pass through structurally).
        dec_trees = {
            name: jax.tree_util.tree_unflatten(
                treedef,
                [
                    decompress_moment(s) if isinstance(s, QuantizedTensor) else s
                    for s in comp_leaves[name]
                ],
            )
            for name in field_names
        }

        # Alg. 1 line 4: the inner optimizer A.  Kernel-routed leaves are
        # recomputed below and their reference results DCE'd under jit.
        inner_updates, new_inner = inner.update(
            updates, state.inner._replace(**dec_trees), params, key=key
        )
        u_leaves = treedef.flatten_up_to(inner_updates)
        new_leaves = {
            name: treedef.flatten_up_to(getattr(new_inner, name))
            for name in field_names
        }

        out_u = []
        out_state = {name: [] for name in field_names}
        for i in range(n):
            comp_i = {name: comp_leaves[name][i] for name in field_names}
            leaf_key = jax.random.fold_in(key, i) if key is not None else None
            if kernel is not None and kernel.eligible(comp_i, leaves_p[i]):
                w_new, new_comp = kernel.run(
                    leaves_p[i], leaves_g[i], comp_i, count, key=leaf_key
                )
                out_u.append(Replace(w_new))
                for name in field_names:
                    out_state[name].append(new_comp[name])
                continue

            # Alg. 1 line 5: recompress, with per-leaf/per-moment SR keys.
            if leaf_key is not None and len(field_names) > 1:
                field_keys = dict(
                    zip(field_names, jax.random.split(leaf_key, len(field_names)))
                )
            else:
                field_keys = {name: leaf_key for name in field_names}
            out_u.append(u_leaves[i])
            for name in field_names:
                old = comp_i[name]
                new = new_leaves[name][i]
                if isinstance(old, QuantizedTensor):
                    out_state[name].append(
                        quantize(new, old.config, key=field_keys[name])
                    )
                else:
                    out_state[name].append(new)

        replacements = {
            name: jax.tree_util.tree_unflatten(treedef, out_state[name])
            for name in field_names
        }
        return (
            jax.tree_util.tree_unflatten(treedef, out_u),
            CompressedState(count, new_inner._replace(**replacements)),
        )

    return GradientTransformation(init, update)


# ---------------------------------------------------------------------------
# partition(): per-subtree transform routing (optax.multi_transform-style)
# ---------------------------------------------------------------------------


class MaskedNode(NamedTuple):
    """Placeholder for leaves owned by a different partition (no children,
    so masked positions simply vanish from flattened views)."""


@jax.tree_util.register_pytree_with_keys_class
class PartitionState:
    """Per-label sub-states plus the init-time param paths (static aux).

    Recording the paths lets ``update`` detect a param tree that drifted
    since ``init`` — a leaf added after init raises ``KeyError`` instead of
    silently training it with garbage (or no) state.
    """

    __slots__ = ("states", "param_paths")

    def __init__(self, states, param_paths=None):
        self.states = dict(states)
        self.param_paths = None if param_paths is None else tuple(param_paths)

    def tree_flatten_with_keys(self):
        items = sorted(self.states.items())
        return (
            tuple((jax.tree_util.DictKey(k), v) for k, v in items),
            (tuple(k for k, _ in items), self.param_paths),
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        keys, paths = aux
        return cls(dict(zip(keys, children)), paths)

    def __repr__(self) -> str:  # pragma: no cover
        return f"PartitionState(labels={sorted(self.states)})"


def label_by_regex(
    patterns, match_label: str, default_label: str
) -> Callable[[str, Any], str]:
    """Label fn: ``match_label`` when the '/'-joined leaf path matches any
    regex, else ``default_label``.  Subsumes ``QuantPolicy.exclude`` at the
    whole-optimizer level (e.g. fp32-AdamW embeddings + 4-bit body)."""
    pats = tuple(patterns)

    def fn(path: str, leaf) -> str:
        del leaf
        return (
            match_label
            if any(re.search(p, path) for p in pats)
            else default_label
        )

    return fn


def partition(
    transforms: Mapping[str, GradientTransformation],
    labels,
) -> GradientTransformation:
    """Route parameter subtrees to different transforms.

    ``labels`` is either a pytree of label strings matching ``params`` or a
    callable ``(path, param) -> label``.  Every label must name an entry of
    ``transforms``.  Each sub-transform sees the full tree with non-owned
    leaves replaced by ``MaskedNode`` (which flatten to nothing), so leaf
    paths — and hence ``QuantPolicy`` decisions — are unchanged.

    Label resolution (path building + regex matching) is cached by the param
    tree's (treedef, leaf shapes): labels are pure functions of structure and
    shape, so steady-state ``update`` calls skip the per-leaf regex walk
    entirely instead of re-labelling every step.
    """
    transforms = dict(transforms)
    _resolved_cache: Dict[Any, Tuple[Any, Tuple[str, ...], Tuple[str, ...]]] = {}

    def _mask(tree, lab_tree, label):
        return jax.tree_util.tree_map(
            lambda x, l: x if l == label else MaskedNode(), tree, lab_tree
        )

    def _check(lab_leaves):
        for l in lab_leaves:
            if l not in transforms:
                raise ValueError(
                    f"partition(): label {l!r} has no transform; "
                    f"known labels: {sorted(transforms)}"
                )

    def _resolved(params):
        """(label tree, label leaves, param paths), cached per tree layout.

        The key covers everything a label fn may legitimately inspect about a
        leaf (structure, shape, dtype) — value-dependent labels would be
        untraceable under jit anyway.
        """
        treedef = jax.tree_util.tree_structure(params)
        shapes = tuple(
            (tuple(getattr(p, "shape", ())), str(getattr(p, "dtype", "")))
            for p in jax.tree_util.tree_leaves(params)
        )
        cache_key = (treedef, shapes)
        hit = _resolved_cache.get(cache_key)
        if hit is None:
            paths_tree = tree_paths(params)
            if callable(labels):
                lab_tree = jax.tree_util.tree_map(labels, paths_tree, params)
            else:
                lab_tree = labels
            lab_leaves = tuple(jax.tree_util.tree_leaves(lab_tree))
            _check(lab_leaves)
            paths = tuple(jax.tree_util.tree_leaves(paths_tree))
            hit = (lab_tree, lab_leaves, paths)
            _resolved_cache[cache_key] = hit
        return hit

    def init(params):
        lab_tree, _, paths = _resolved(params)
        return PartitionState(
            {
                lab: tx.init(_mask(params, lab_tree, lab))
                for lab, tx in transforms.items()
            },
            paths,
        )

    def update(updates, state, params=None, *, key=None):
        lab_tree, lab_leaves, cur = _resolved(params)
        if state.param_paths is not None:
            if cur != state.param_paths:
                added = set(cur) - set(state.param_paths)
                removed = set(state.param_paths) - set(cur)
                raise KeyError(
                    "partition(): param tree changed since init() — "
                    f"added {sorted(added)}, removed {sorted(removed)}; "
                    "re-init the optimizer state (or migrate it) instead of "
                    "training new params with stale partition state"
                )
        treedef = jax.tree_util.tree_structure(lab_tree)

        # Distinct SR key per partition: leaf indices restart at 0 inside each
        # masked subtree, so handing every partition the same key would give
        # correlated quantization noise across partitions.
        label_order = {lab: i for i, lab in enumerate(sorted(transforms))}
        per_label_u: Dict[str, Any] = {}
        new_states: Dict[str, Any] = {}
        for lab, tx in transforms.items():
            k_lab = (
                jax.random.fold_in(key, label_order[lab]) if key is not None else None
            )
            u_l, s_l = tx.update(
                _mask(updates, lab_tree, lab),
                state.states[lab],
                _mask(params, lab_tree, lab),
                key=k_lab,
            )
            per_label_u[lab] = treedef.flatten_up_to(u_l)
            new_states[lab] = s_l

        merged = [per_label_u[lab][i] for i, lab in enumerate(lab_leaves)]
        return (
            jax.tree_util.tree_unflatten(treedef, merged),
            PartitionState(new_states, state.param_paths),
        )

    return GradientTransformation(init, update)
