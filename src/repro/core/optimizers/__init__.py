"""Optimizer zoo: the paper's 4-bit optimizers plus every compared baseline."""

from repro.core.optimizers.adafactor import adafactor
from repro.core.optimizers.adamw import (
    M_4BIT,
    M_8BIT,
    V_4BIT,
    V_8BIT,
    adamw32,
    adamw4bit,
    adamw8bit,
    factor4bit,
    quantized_adamw,
)
from repro.core.optimizers.base import (
    FactoredMoment,
    Optimizer,
    QuantPolicy,
    state_nbytes,
)
from repro.core.optimizers.schedule import (
    constant,
    linear_warmup_cosine,
    linear_warmup_linear_decay,
)
from repro.core.optimizers.sgdm import sgdm, sgdm4bit
from repro.core.optimizers.sm3 import sm3

OPTIMIZER_REGISTRY = {
    "adamw32": adamw32,
    "adamw8bit": adamw8bit,
    "adamw4bit": adamw4bit,
    "factor4bit": factor4bit,
    "adafactor": adafactor,
    "sm3": sm3,
    "sgdm": sgdm,
    "sgdm4bit": sgdm4bit,
}

__all__ = [
    "Optimizer",
    "QuantPolicy",
    "FactoredMoment",
    "state_nbytes",
    "quantized_adamw",
    "adamw32",
    "adamw8bit",
    "adamw4bit",
    "factor4bit",
    "adafactor",
    "sm3",
    "sgdm",
    "sgdm4bit",
    "constant",
    "linear_warmup_linear_decay",
    "linear_warmup_cosine",
    "OPTIMIZER_REGISTRY",
    "M_4BIT",
    "V_4BIT",
    "M_8BIT",
    "V_8BIT",
]
