"""Optimizer zoo: the paper's 4-bit optimizers plus every compared baseline.

The zoo is built on the composable transform API in
``repro.core.optimizers.transform`` (``chain`` / ``compressed`` /
``partition``); the paper-named constructors are thin chains, and
``make_optimizer(name, lr, **overrides)`` is the structured factory used by
CLIs and benchmarks (overrides are validated against each constructor's
signature).
"""

from __future__ import annotations

import difflib
import inspect
from typing import Callable, Dict, NamedTuple, Tuple

from repro.core.optimizers.adafactor import adafactor
from repro.core.optimizers.adamw import (
    M_4BIT,
    M_8BIT,
    V_4BIT,
    V_8BIT,
    adamw32,
    adamw4bit,
    adamw8bit,
    factor4bit,
    quantized_adamw,
)
from repro.core.optimizers.base import (
    FactoredMoment,
    Optimizer,
    QuantPolicy,
    state_nbytes,
)
from repro.core.optimizers.schedule import (
    constant,
    linear_warmup_cosine,
    linear_warmup_linear_decay,
)
from repro.core.optimizers.presets import (
    PRODUCTION_FP32_PATTERNS,
    production4bit,
    production_labels,
)
from repro.core.optimizers.sgdm import sgdm, sgdm4bit
from repro.core.optimizers.shampoo import (
    FACTOR_4BIT,
    shampoo_chain,
    shampoo32,
    shampoo4bit,
)
from repro.core.optimizers.sm3 import sm3
from repro.core.optimizers.transform import (
    GradientTransformation,
    add_decayed_weights,
    as_optimizer,
    chain,
    compressed,
    label_by_regex,
    partition,
    scale_by_adam,
    scale_by_factored_rms,
    scale_by_learning_rate,
    scale_by_shampoo,
    scale_by_sm3,
    trace,
)


class OptimizerSpec(NamedTuple):
    """Registry entry: the chain-building factory plus its doc line.

    ``forwards_to`` names the constructor a factory's ``**kw`` is handed to,
    so override validation checks the real target's signature.
    """

    factory: Callable[..., Optimizer]
    description: str
    forwards_to: Callable[..., Optimizer] = None


OPTIMIZER_SPECS: Dict[str, OptimizerSpec] = {
    "adamw32": OptimizerSpec(
        adamw32, "32-bit AdamW (no compression)", quantized_adamw
    ),
    "adamw8bit": OptimizerSpec(
        adamw8bit, "8-bit AdamW baseline, B2048/DE, embeddings fp32", quantized_adamw
    ),
    "adamw4bit": OptimizerSpec(
        adamw4bit, "paper's 4-bit AdamW: m B128/DE, v Rank-1/Linear", quantized_adamw
    ),
    "factor4bit": OptimizerSpec(
        factor4bit, "paper's 4-bit Factor: m B128/DE, v factored for ndim>=2",
        quantized_adamw,
    ),
    "adafactor": OptimizerSpec(adafactor, "Adafactor baseline (factored v)"),
    "sm3": OptimizerSpec(sm3, "SM3 baseline (sublinear accumulators)"),
    "sgdm": OptimizerSpec(sgdm, "SGD with momentum (Alg. 2 accumulator form)"),
    "sgdm4bit": OptimizerSpec(
        sgdm4bit, "4-bit SGDM with stochastic rounding", sgdm
    ),
    "production4bit": OptimizerSpec(
        production4bit,
        "production preset: fp32 embed/head/norm/bias + 4-bit SR body",
    ),
    "shampoo32": OptimizerSpec(
        shampoo32,
        "fp32 blocked Shampoo with AdamW grafting (parity oracle)",
        shampoo_chain,
    ),
    "shampoo4bit": OptimizerSpec(
        shampoo4bit,
        "4-bit Shampoo: B128/Dyn Kronecker factors + 4-bit AdamW moments",
        shampoo_chain,
    ),
}


def optimizer_names() -> Tuple[str, ...]:
    return tuple(OPTIMIZER_SPECS)


def make_optimizer(name: str, lr, **overrides) -> Optimizer:
    """Build a registered optimizer with validated keyword overrides.

    Raises ``ValueError`` for an unknown name or an override the named
    constructor does not accept (listing the valid choices), so CLI typos
    fail loudly instead of silently training the wrong configuration.
    """
    spec = OPTIMIZER_SPECS.get(name)
    if spec is None:
        close = difflib.get_close_matches(str(name), OPTIMIZER_SPECS, n=1)
        hint = f" — did you mean {close[0]!r}?" if close else ""
        raise ValueError(
            f"unknown optimizer {name!r}; available: {', '.join(OPTIMIZER_SPECS)}"
            f"{hint}"
        )
    valid = set()
    fn = spec.factory
    while fn is not None:  # follow the **kw forwarding chain
        sig = inspect.signature(fn)
        valid |= {
            p.name
            for p in sig.parameters.values()
            if p.kind in (inspect.Parameter.POSITIONAL_OR_KEYWORD, inspect.Parameter.KEYWORD_ONLY)
            and p.name != "lr"
        }
        has_var_kw = any(
            p.kind is inspect.Parameter.VAR_KEYWORD for p in sig.parameters.values()
        )
        next_fn = spec.forwards_to if fn is spec.factory else None
        fn = next_fn if has_var_kw else None
    unknown = set(overrides) - valid
    if unknown:
        hints = []
        for k in sorted(unknown):
            close = difflib.get_close_matches(k, valid, n=1)
            if close:
                hints.append(f"{k!r} -> did you mean {close[0]!r}?")
        hint = (" " + "; ".join(hints)) if hints else ""
        raise ValueError(
            f"optimizer {name!r} does not accept override(s) "
            f"{sorted(unknown)}; valid overrides: {sorted(valid)}.{hint}"
        )
    try:
        return spec.factory(lr, **overrides)
    except TypeError as e:
        # e.g. a forwarded param the wrapper hard-binds ("multiple values")
        raise ValueError(
            f"optimizer {name!r} rejected overrides: {e}"
        ) from None


__all__ = [
    # facade + policies
    "Optimizer",
    "QuantPolicy",
    "FactoredMoment",
    "state_nbytes",
    # transform API
    "GradientTransformation",
    "chain",
    "compressed",
    "partition",
    "label_by_regex",
    "as_optimizer",
    "scale_by_adam",
    "trace",
    "scale_by_sm3",
    "scale_by_factored_rms",
    "scale_by_shampoo",
    "add_decayed_weights",
    "scale_by_learning_rate",
    # paper-named constructors
    "quantized_adamw",
    "production4bit",
    "production_labels",
    "PRODUCTION_FP32_PATTERNS",
    "adamw32",
    "adamw8bit",
    "adamw4bit",
    "factor4bit",
    "adafactor",
    "sm3",
    "sgdm",
    "sgdm4bit",
    "shampoo_chain",
    "shampoo32",
    "shampoo4bit",
    # schedules
    "constant",
    "linear_warmup_linear_decay",
    "linear_warmup_cosine",
    # factory
    "OptimizerSpec",
    "OPTIMIZER_SPECS",
    "make_optimizer",
    "optimizer_names",
    # quantizer presets
    "M_4BIT",
    "V_4BIT",
    "M_8BIT",
    "V_8BIT",
    "FACTOR_4BIT",
]
