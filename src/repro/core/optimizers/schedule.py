"""Learning-rate schedules (linear warmup+decay as in the paper's App. D)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["constant", "linear_warmup_linear_decay", "linear_warmup_cosine"]


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_warmup_linear_decay(lr: float, warmup: int, total: int):
    """The schedule used across the paper's fine-tuning benchmarks."""

    def f(step):
        step = step.astype(jnp.float32)
        warm = lr * step / jnp.maximum(1.0, float(warmup))
        decay = lr * jnp.maximum(
            0.0, (float(total) - step) / jnp.maximum(1.0, float(total - warmup))
        )
        return jnp.where(step < warmup, warm, decay)

    return f


def linear_warmup_cosine(lr: float, warmup: int, total: int, final_frac: float = 0.1):
    def f(step):
        step = step.astype(jnp.float32)
        warm = lr * step / jnp.maximum(1.0, float(warmup))
        t = jnp.clip((step - warmup) / jnp.maximum(1.0, float(total - warmup)), 0, 1)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, lr * cos)

    return f
