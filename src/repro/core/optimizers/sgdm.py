"""SGDM and compressed SGDM (paper Alg. 2, used by Theorem 1).

Note Alg. 2 uses the *accumulator* convention m_t = β m_{t-1} + g_t (no
(1-β) damping), matching the theorem's constants.
"""

from __future__ import annotations

from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp

from repro.core.optimizers.base import (
    Optimizer,
    QuantPolicy,
    compress_moment,
    decompress_moment,
    tree_paths,
)
from repro.core.quantizer import QuantizedTensor

__all__ = ["sgdm", "sgdm4bit"]

Schedule = Union[float, Callable[[jnp.ndarray], jnp.ndarray]]


def sgdm(
    lr: Schedule,
    beta: float = 0.9,
    weight_decay: float = 0.0,
    m_policy: Optional[QuantPolicy] = None,
    name: str = "sgdm",
) -> Optimizer:
    m_policy = m_policy or QuantPolicy()

    def init(params):
        paths = tree_paths(params)

        def init_m(path, p):
            mode = m_policy.mode(path, p.shape)
            return compress_moment(
                jnp.zeros(p.shape, jnp.float32), mode, m_policy.config
            )

        return {
            "m": jax.tree_util.tree_map(init_m, paths, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, key=None):
        step = state["step"] + 1
        lr_t = lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)

        is_leaf = lambda x: isinstance(x, QuantizedTensor)
        leaves_g, treedef = jax.tree_util.tree_flatten(grads)
        leaves_p = treedef.flatten_up_to(params)
        leaves_m = jax.tree_util.tree_flatten(state["m"], is_leaf=is_leaf)[0]

        new_p, new_m = [], []
        for i, (g, p, m_s) in enumerate(zip(leaves_g, leaves_p, leaves_m)):
            g = g.astype(jnp.float32)
            m = decompress_moment(m_s)
            m = beta * m + g  # Alg. 2 line 4 (accumulator form)
            p2 = (
                p.astype(jnp.float32) - lr_t * (m + weight_decay * p)
            ).astype(p.dtype)
            if isinstance(m_s, QuantizedTensor):
                leaf_key = (
                    jax.random.fold_in(key, i) if key is not None else None
                )
                m2 = compress_moment(m, "quant", m_s.config, key=leaf_key)
            else:
                m2 = m
            new_p.append(p2)
            new_m.append(m2)

        return (
            jax.tree_util.tree_unflatten(treedef, new_p),
            {"m": jax.tree_util.tree_unflatten(treedef, new_m), "step": step},
        )

    return Optimizer(init=init, update=update, name=name)


def sgdm4bit(lr: Schedule, beta: float = 0.9, stochastic_rounding: bool = True, **kw) -> Optimizer:
    """Compressed SGDM (Alg. 2) with 4-bit B128/DE momentum.

    Stochastic rounding by default: Theorem 1 assumes an *unbiased* quantizer
    (Assumption 4), which round-to-nearest does not satisfy.
    """
    from repro.core.quantizer import QuantConfig

    cfg = QuantConfig(
        bits=4,
        normalization="blockwise",
        block_size=128,
        mapping="de",
        signed=True,
        stochastic_rounding=stochastic_rounding,
    )
    return sgdm(lr, beta=beta, m_policy=QuantPolicy(config=cfg), name="sgdm4bit", **kw)
