"""SGDM and compressed SGDM (paper Alg. 2, used by Theorem 1).

Note Alg. 2 uses the *accumulator* convention m_t = β m_{t-1} + g_t (no
(1-β) damping), matching the theorem's constants.  Built as
``chain(compressed(trace(β), {"trace": policy}), add_decayed_weights,
scale_by_learning_rate)`` — the momentum state field is named ``trace``
(reachable as ``state["trace"]`` on the chain state).
"""

from __future__ import annotations

from typing import Optional

from repro.core.optimizers.base import Optimizer, QuantPolicy
from repro.core.optimizers.transform import (
    Schedule,
    add_decayed_weights,
    as_optimizer,
    chain,
    compressed,
    scale_by_learning_rate,
    trace,
)

__all__ = ["sgdm", "sgdm4bit"]


def sgdm(
    lr: Schedule,
    beta: float = 0.9,
    weight_decay: float = 0.0,
    m_policy: Optional[QuantPolicy] = None,
    name: str = "sgdm",
) -> Optimizer:
    m_policy = m_policy or QuantPolicy()
    tx = chain(
        compressed(trace(beta), {"trace": m_policy}),
        add_decayed_weights(weight_decay),
        scale_by_learning_rate(lr),
    )
    return as_optimizer(tx, name=name)


def sgdm4bit(lr: Schedule, beta: float = 0.9, stochastic_rounding: bool = True, **kw) -> Optimizer:
    """Compressed SGDM (Alg. 2) with 4-bit B128/DE momentum.

    Stochastic rounding by default: Theorem 1 assumes an *unbiased* quantizer
    (Assumption 4), which round-to-nearest does not satisfy.
    """
    from repro.core.quantizer import QuantConfig

    cfg = QuantConfig(
        bits=4,
        normalization="blockwise",
        block_size=128,
        mapping="de",
        signed=True,
        stochastic_rounding=stochastic_rounding,
    )
    return sgdm(lr, beta=beta, m_policy=QuantPolicy(config=cfg), name="sgdm4bit", **kw)
