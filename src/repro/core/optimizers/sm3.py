"""SM3 baseline (Anil et al. 2019), as compared in the paper.

Sublinear memory: one accumulator vector per tensor dimension (the cover of
co-dimension-1 slices used in the SM3 paper's experiments). The β1>0 momentum
variant matches the paper's comparison setup.  The update rule lives in
``transform.scale_by_sm3``; this module is just the paper-named chain.
"""

from __future__ import annotations

from repro.core.optimizers.base import Optimizer
from repro.core.optimizers.transform import (
    Schedule,
    add_decayed_weights,
    as_optimizer,
    chain,
    scale_by_learning_rate,
    scale_by_sm3,
)

__all__ = ["sm3"]


def sm3(
    lr: Schedule,
    b1: float = 0.9,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
) -> Optimizer:
    tx = chain(
        scale_by_sm3(b1=b1, eps=eps),
        add_decayed_weights(weight_decay),
        scale_by_learning_rate(lr),
    )
    return as_optimizer(tx, name="sm3")
