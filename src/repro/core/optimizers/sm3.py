"""SM3 baseline (Anil et al. 2019), as compared in the paper.

Sublinear memory: one accumulator vector per tensor dimension (the cover of
co-dimension-1 slices used in the SM3 paper's experiments). The β1>0 momentum
variant matches the paper's comparison setup.
"""

from __future__ import annotations

from typing import Callable, Union

import jax
import jax.numpy as jnp

from repro.core.optimizers.base import Optimizer

__all__ = ["sm3"]

Schedule = Union[float, Callable[[jnp.ndarray], jnp.ndarray]]


def _broadcast_min(accs, shape):
    """nu_ij = min_r acc_r[i_r] broadcast to ``shape`` (Alg. 4 style)."""
    out = None
    for r, acc in enumerate(accs):
        view = [1] * len(shape)
        view[r] = shape[r]
        b = acc.reshape(view)
        out = b if out is None else jnp.minimum(out, b)
    return jnp.broadcast_to(out, shape)


def sm3(
    lr: Schedule,
    b1: float = 0.9,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
) -> Optimizer:
    def init(params):
        def init_acc(p):
            if p.ndim == 0:
                return (jnp.zeros((1,), jnp.float32),)
            return tuple(jnp.zeros((d,), jnp.float32) for d in p.shape)

        return {
            "acc": jax.tree_util.tree_map(
                init_acc, params, is_leaf=lambda x: hasattr(x, "shape")
            ),
            "m": jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            ),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, key=None):
        del key
        step = state["step"] + 1
        lr_t = lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)

        leaves_g, treedef = jax.tree_util.tree_flatten(grads)
        leaves_p = treedef.flatten_up_to(params)
        leaves_acc = treedef.flatten_up_to(state["acc"])
        leaves_m = treedef.flatten_up_to(state["m"])

        new_p, new_acc, new_m = [], [], []
        for g, p, accs, m in zip(leaves_g, leaves_p, leaves_acc, leaves_m):
            g = g.astype(jnp.float32)
            shape = g.shape if g.ndim > 0 else (1,)
            g_ = g.reshape(shape)
            nu = _broadcast_min(accs, shape) + g_ * g_
            accs2 = tuple(
                jnp.max(nu, axis=tuple(i for i in range(len(shape)) if i != r))
                for r in range(len(shape))
            )
            u = (g_ / (jnp.sqrt(nu) + eps)).reshape(g.shape)
            m2 = b1 * m + (1 - b1) * u
            p2 = (p.astype(jnp.float32) - lr_t * (m2 + weight_decay * p)).astype(
                p.dtype
            )
            new_p.append(p2)
            new_acc.append(accs2)
            new_m.append(m2)

        return (
            jax.tree_util.tree_unflatten(treedef, new_p),
            {
                "acc": jax.tree_util.tree_unflatten(treedef, new_acc),
                "m": jax.tree_util.tree_unflatten(treedef, new_m),
                "step": step,
            },
        )

    return Optimizer(init=init, update=update, name="sm3")
