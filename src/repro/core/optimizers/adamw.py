"""AdamW with compressed optimizer states (paper Alg. 3).

One factory covers the paper's whole AdamW family:

* 32-bit AdamW        — ``adamw32(lr)``                       (no compression)
* 8-bit  AdamW [15]   — ``adamw8bit(lr)``   B2048/DE both moments, embeddings
                        excluded (faithful to Dettmers et al.)
* 4-bit  AdamW (ours) — ``adamw4bit(lr)``   m: B128/DE (signed),
                        v: Rank-1/Linear (unsigned, zero excluded)
* 4-bit  Factor(ours) — ``factor4bit(lr)``  m: B128/DE; v factored for
                        ndim>=2, quantized Rank-1/Linear for 1-d

Per-leaf state is chosen by ``QuantPolicy`` (threshold 4096, App. D.1). The
update is Alg. 1: decompress -> AdamW step -> compress; only the compressed
states persist between steps.
"""

from __future__ import annotations

from typing import Callable, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.optimizers.base import (
    FactoredMoment,
    Optimizer,
    QuantPolicy,
    compress_moment,
    decompress_moment,
    tree_paths,
)
from repro.core.quantizer import QuantConfig, QuantizedTensor

__all__ = ["quantized_adamw", "adamw32", "adamw8bit", "adamw4bit", "factor4bit"]

Schedule = Union[float, Callable[[jnp.ndarray], jnp.ndarray]]

# Paper-named quantizer presets (Sec. 5).
M_4BIT = QuantConfig(bits=4, normalization="blockwise", block_size=128, mapping="de", signed=True)
V_4BIT = QuantConfig(bits=4, normalization="rank1", mapping="linear", signed=False)
M_8BIT = QuantConfig(bits=8, normalization="blockwise", block_size=2048, mapping="de", signed=True)
V_8BIT = QuantConfig(bits=8, normalization="blockwise", block_size=2048, mapping="de", signed=False)


def _resolve_lr(lr: Schedule, step: jnp.ndarray) -> jnp.ndarray:
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


def quantized_adamw(
    lr: Schedule,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
    m_policy: Optional[QuantPolicy] = None,
    v_policy: Optional[QuantPolicy] = None,
    use_kernel: bool = False,
    name: str = "adamw",
) -> Optimizer:
    """AdamW whose moments are stored per ``QuantPolicy`` (None => fp32).

    ``use_kernel`` routes eligible leaves (4-bit m, 2-d tensors) through the
    fused Pallas update in ``repro.kernels.ops`` instead of the reference
    dequant->update->requant composition.
    """
    m_policy = m_policy or QuantPolicy()
    v_policy = v_policy or QuantPolicy()

    def init(params):
        paths = tree_paths(params)

        def init_m(path, p):
            mode = m_policy.mode(path, p.shape)
            zero = jnp.zeros(p.shape, jnp.float32)
            return compress_moment(zero, mode, m_policy.config)

        def init_v(path, p):
            mode = v_policy.mode(path, p.shape)
            if mode == "factor":
                return FactoredMoment.zeros(p.shape)
            zero = jnp.zeros(p.shape, jnp.float32)
            return compress_moment(zero, mode, v_policy.config)

        return {
            "m": jax.tree_util.tree_map(init_m, paths, params),
            "v": jax.tree_util.tree_map(init_v, paths, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, key: Optional[jax.Array] = None):
        step = state["step"] + 1
        lr_t = _resolve_lr(lr, step)
        bc1 = 1.0 - jnp.power(jnp.float32(b1), step.astype(jnp.float32))
        bc2 = 1.0 - jnp.power(jnp.float32(b2), step.astype(jnp.float32))

        leaves_g, treedef = jax.tree_util.tree_flatten(grads)
        leaves_p = treedef.flatten_up_to(params)
        is_state_leaf = lambda x: isinstance(x, (QuantizedTensor, FactoredMoment))
        leaves_m = jax.tree_util.tree_flatten(state["m"], is_leaf=is_state_leaf)[0]
        leaves_v = jax.tree_util.tree_flatten(state["v"], is_leaf=is_state_leaf)[0]

        new_p, new_m, new_v = [], [], []
        for i, (g, p, m_s, v_s) in enumerate(
            zip(leaves_g, leaves_p, leaves_m, leaves_v)
        ):
            leaf_key = None
            if key is not None:
                leaf_key = jax.random.fold_in(key, i)
            if use_kernel and _kernel_eligible(m_s, v_s, p):
                from repro.kernels import ops as kernel_ops

                p2, m2, v2 = kernel_ops.fused_adamw4_leaf(
                    p, g, m_s, v_s, lr_t, b1, b2, eps, weight_decay, bc1, bc2
                )
            else:
                p2, m2, v2 = _reference_leaf_update(
                    p, g, m_s, v_s, lr_t, b1, b2, eps, weight_decay, bc1, bc2,
                    leaf_key,
                )
            new_p.append(p2)
            new_m.append(m2)
            new_v.append(v2)

        return (
            jax.tree_util.tree_unflatten(treedef, new_p),
            {
                "m": jax.tree_util.tree_unflatten(treedef, new_m),
                "v": jax.tree_util.tree_unflatten(treedef, new_v),
                "step": step,
            },
        )

    return Optimizer(init=init, update=update, name=name)


def _kernel_eligible(m_s, v_s, p) -> bool:
    return (
        isinstance(m_s, QuantizedTensor)
        and m_s.config.bits == 4
        and m_s.config.normalization == "blockwise"
        and m_s.config.block_size == 128
        and not m_s.config.stochastic_rounding
        and isinstance(v_s, QuantizedTensor)
        and v_s.config.bits == 4
        and v_s.config.normalization == "rank1"
        and not v_s.config.stochastic_rounding
        and p.ndim == 2
        and p.shape[-1] % 256 == 0  # nibble + B128 tile alignment
    )


def _reference_leaf_update(
    p, g, m_s, v_s, lr_t, b1, b2, eps, weight_decay, bc1, bc2, key
):
    """Alg. 1 lines 3-5 for one leaf: decompress, AdamW (Eq. 1), compress."""
    g = g.astype(jnp.float32)
    m = decompress_moment(m_s)
    m = b1 * m + (1.0 - b1) * g

    if isinstance(v_s, FactoredMoment):
        v_fac = v_s.ema_update(g * g, b2)
        v = v_fac.reconstruct()
        new_v = v_fac
    else:
        v = decompress_moment(v_s)
        v = b2 * v + (1.0 - b2) * g * g
        new_v = None  # compressed below

    m_hat = m / bc1
    v_hat = v / bc2
    update = m_hat / (jnp.sqrt(v_hat) + eps)
    p2 = (p.astype(jnp.float32) - lr_t * (update + weight_decay * p)).astype(p.dtype)

    m_key = v_key = None
    if key is not None:
        m_key, v_key = jax.random.split(key)
    if isinstance(m_s, QuantizedTensor):
        m2 = compress_moment(m, "quant", m_s.config, key=m_key)
    else:
        m2 = m
    if new_v is None:
        if isinstance(v_s, QuantizedTensor):
            new_v = compress_moment(v, "quant", v_s.config, key=v_key)
        else:
            new_v = v
    return p2, m2, new_v


# ---------------------------------------------------------------------------
# Paper-named constructors
# ---------------------------------------------------------------------------


def adamw32(lr: Schedule, **kw) -> Optimizer:
    return quantized_adamw(lr, name="adamw32", **kw)


def adamw8bit(lr: Schedule, exclude_embeddings: bool = True, **kw) -> Optimizer:
    """8-bit AdamW baseline [Dettmers et al. 2022]: B2048/DE, embeddings fp32."""
    exclude = ("embed",) if exclude_embeddings else ()
    return quantized_adamw(
        lr,
        m_policy=QuantPolicy(config=M_8BIT, exclude=exclude),
        v_policy=QuantPolicy(config=V_8BIT, exclude=exclude),
        name="adamw8bit",
        **kw,
    )


def adamw4bit(lr: Schedule, stochastic_rounding: bool = False, use_kernel: bool = False, **kw) -> Optimizer:
    """The paper's 4-bit AdamW: m B128/DE, v Rank-1/Linear (zero excluded)."""
    m_cfg = M_4BIT
    v_cfg = V_4BIT
    if stochastic_rounding:
        m_cfg = QuantConfig(**{**m_cfg.__dict__, "stochastic_rounding": True})
        v_cfg = QuantConfig(**{**v_cfg.__dict__, "stochastic_rounding": True})
    return quantized_adamw(
        lr,
        m_policy=QuantPolicy(config=m_cfg),
        v_policy=QuantPolicy(config=v_cfg),
        use_kernel=use_kernel,
        name="adamw4bit",
        **kw,
    )


def factor4bit(lr: Schedule, **kw) -> Optimizer:
    """The paper's 4-bit Factor: m B128/DE; v factored (>=2-d) else 4-bit."""
    return quantized_adamw(
        lr,
        m_policy=QuantPolicy(config=M_4BIT),
        v_policy=QuantPolicy(config=V_4BIT, factor_2d=True),
        name="factor4bit",
        **kw,
    )
