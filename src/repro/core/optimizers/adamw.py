"""AdamW family as transformation chains (paper Alg. 3).

One builder covers the paper's whole AdamW family:

* 32-bit AdamW        — ``adamw32(lr)``                       (no compression)
* 8-bit  AdamW [15]   — ``adamw8bit(lr)``   B2048/DE both moments, embeddings
                        excluded (faithful to Dettmers et al.)
* 4-bit  AdamW (ours) — ``adamw4bit(lr)``   m: B128/DE (signed),
                        v: Rank-1/Linear (unsigned, zero excluded)
* 4-bit  Factor(ours) — ``factor4bit(lr)``  m: B128/DE; v factored for
                        ndim>=2, quantized Rank-1/Linear for 1-d

Each is ``chain(compressed(scale_by_adam(...), policies),
add_decayed_weights(wd), scale_by_learning_rate(lr))`` — the Alg. 1
decompress -> step -> compress machinery lives once in
``transform.compressed``, with per-leaf state chosen by ``QuantPolicy``
(threshold 4096, App. D.1).  ``use_kernel=True`` attaches a
``FusedAdamWRoute`` so eligible leaves run the fused Pallas kernel.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.optimizers.base import Optimizer, QuantPolicy
from repro.core.optimizers.transform import (
    FusedAdamWRoute,
    Schedule,
    add_decayed_weights,
    as_optimizer,
    chain,
    compressed,
    scale_by_adam,
    scale_by_learning_rate,
)
from repro.core.quantizer import QuantConfig

__all__ = [
    "adamw_chain",
    "quantized_adamw",
    "adamw32",
    "adamw8bit",
    "adamw4bit",
    "factor4bit",
]

# Paper-named quantizer presets (Sec. 5).
M_4BIT = QuantConfig(bits=4, normalization="blockwise", block_size=128, mapping="de", signed=True)
V_4BIT = QuantConfig(bits=4, normalization="rank1", mapping="linear", signed=False)
M_8BIT = QuantConfig(bits=8, normalization="blockwise", block_size=2048, mapping="de", signed=True)
V_8BIT = QuantConfig(bits=8, normalization="blockwise", block_size=2048, mapping="de", signed=False)


def adamw_chain(
    lr: Schedule,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
    m_policy: Optional[QuantPolicy] = None,
    v_policy: Optional[QuantPolicy] = None,
    use_kernel: bool = False,
):
    """The bare AdamW transformation chain (no ``Optimizer`` facade) — the
    building block ``partition()`` presets compose per-subtree."""
    m_policy = m_policy or QuantPolicy()
    v_policy = v_policy or QuantPolicy()
    kernel = (
        FusedAdamWRoute(lr=lr, b1=b1, b2=b2, eps=eps, weight_decay=weight_decay)
        if use_kernel
        else None
    )
    return chain(
        compressed(
            scale_by_adam(b1=b1, b2=b2, eps=eps),
            {"m": m_policy, "v": v_policy},
            kernel=kernel,
        ),
        add_decayed_weights(weight_decay),
        scale_by_learning_rate(lr),
    )


def quantized_adamw(
    lr: Schedule,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
    m_policy: Optional[QuantPolicy] = None,
    v_policy: Optional[QuantPolicy] = None,
    use_kernel: bool = False,
    name: str = "adamw",
) -> Optimizer:
    """AdamW whose moments are stored per ``QuantPolicy`` (None => fp32).

    ``use_kernel`` routes eligible leaves (4-bit B128 m + rank-1 v, ndim>=2
    tensors with last dim % 256 == 0, round-to-nearest or stochastic
    rounding) through the fused Pallas update in ``repro.kernels.ops``
    instead of the reference dequant->update->requant composition.
    """
    tx = adamw_chain(
        lr,
        b1=b1,
        b2=b2,
        eps=eps,
        weight_decay=weight_decay,
        m_policy=m_policy,
        v_policy=v_policy,
        use_kernel=use_kernel,
    )
    return as_optimizer(tx, name=name)


# ---------------------------------------------------------------------------
# Paper-named constructors
# ---------------------------------------------------------------------------


def adamw32(lr: Schedule, **kw) -> Optimizer:
    return quantized_adamw(lr, name="adamw32", **kw)


def adamw8bit(lr: Schedule, exclude_embeddings: bool = True, **kw) -> Optimizer:
    """8-bit AdamW baseline [Dettmers et al. 2022]: B2048/DE, embeddings fp32."""
    exclude = ("embed",) if exclude_embeddings else ()
    return quantized_adamw(
        lr,
        m_policy=QuantPolicy(config=M_8BIT, exclude=exclude),
        v_policy=QuantPolicy(config=V_8BIT, exclude=exclude),
        name="adamw8bit",
        **kw,
    )


def adamw4bit(lr: Schedule, stochastic_rounding: bool = False, use_kernel: bool = False, **kw) -> Optimizer:
    """The paper's 4-bit AdamW: m B128/DE, v Rank-1/Linear (zero excluded)."""
    m_cfg = M_4BIT
    v_cfg = V_4BIT
    if stochastic_rounding:
        m_cfg = dataclasses.replace(m_cfg, stochastic_rounding=True)
        v_cfg = dataclasses.replace(v_cfg, stochastic_rounding=True)
    return quantized_adamw(
        lr,
        m_policy=QuantPolicy(config=m_cfg),
        v_policy=QuantPolicy(config=v_cfg),
        use_kernel=use_kernel,
        name="adamw4bit",
        **kw,
    )


def factor4bit(lr: Schedule, **kw) -> Optimizer:
    """The paper's 4-bit Factor: m B128/DE; v factored (>=2-d) else 4-bit."""
    return quantized_adamw(
        lr,
        m_policy=QuantPolicy(config=M_4BIT),
        v_policy=QuantPolicy(config=V_4BIT, factor_2d=True),
        name="factor4bit",
        **kw,
    )
