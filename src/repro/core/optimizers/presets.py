"""Production optimizer presets built on ``partition()``.

The paper (Sec. 5) and the 8-bit-optimizers line of work both keep
*sensitive* subtrees in full precision: embeddings (and the untied LM head)
have heavy-tailed, token-sparse moment statistics that 4-bit states track
poorly, while norm scales and biases are tiny — compressing them saves
nothing and risks stability.  ``production4bit`` encodes that split once:

    fp32 partition : embed / head / norm scales / biases  -> uncompressed AdamW
    4-bit partition: everything else                      -> adamw4bit (+SR)

Stochastic rounding defaults ON (the paper's unbiased-quantizer setting,
Alg. 1 + Assumption 4); thread a PRNG key through the train step
(``make_train_state(params, opt, key=...)``) to activate it.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.optimizers.adamw import M_4BIT, V_4BIT, adamw_chain
from repro.core.optimizers.base import Optimizer, QuantPolicy
from repro.core.optimizers.transform import (
    Schedule,
    as_optimizer,
    label_by_regex,
    partition,
)

__all__ = ["PRODUCTION_FP32_PATTERNS", "production_labels", "production4bit"]

# Leaf-path regexes routed to the fp32 partition.  Matches the repo's model
# tree ("embed", "head", "final_norm/scale", per-block "*_norm", layernorm
# "bias") and common external naming ("embedding", "ln_f", ...).
PRODUCTION_FP32_PATTERNS: Tuple[str, ...] = (
    r"embed",
    r"head",
    r"norm",
    r"(^|/)scale($|/)",
    r"(^|/)bias($|/)",
    r"(^|/)ln_",
)


def production_labels(fp32_patterns: Tuple[str, ...] = PRODUCTION_FP32_PATTERNS):
    """Label fn for ``partition()``: 'fp32' for sensitive leaves, '4bit' else."""
    return label_by_regex(fp32_patterns, "fp32", "4bit")


def production4bit(
    lr: Schedule,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
    stochastic_rounding: bool = True,
    use_kernel: bool = True,
    fp32_patterns: Optional[Tuple[str, ...]] = None,
    name: str = "production4bit",
) -> Optimizer:
    """The production training preset: fp32 embeddings/head/norms/biases,
    4-bit (B128/DE m, Rank-1/Linear v) body with stochastic rounding.

    ``fp32_patterns`` overrides which leaf paths stay uncompressed (regexes
    over '/'-joined param paths).  ``use_kernel`` (default on) routes
    eligible body leaves through the fused Pallas whole-step kernel — since
    the kernel requantizes stochastically in-tile (per-leaf SR key, see
    docs/kernels.md), the production SR default keeps the fused fast path.
    """
    m_cfg, v_cfg = M_4BIT, V_4BIT
    if stochastic_rounding:
        m_cfg = dataclasses.replace(m_cfg, stochastic_rounding=True)
        v_cfg = dataclasses.replace(v_cfg, stochastic_rounding=True)
    common = dict(b1=b1, b2=b2, eps=eps, weight_decay=weight_decay)
    tx = partition(
        {
            "fp32": adamw_chain(lr, **common),
            "4bit": adamw_chain(
                lr,
                m_policy=QuantPolicy(config=m_cfg),
                v_policy=QuantPolicy(config=v_cfg),
                use_kernel=use_kernel,
                **common,
            ),
        },
        production_labels(tuple(fp32_patterns or PRODUCTION_FP32_PATTERNS)),
    )
    return as_optimizer(tx, name=name)
