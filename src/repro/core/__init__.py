"""Core: the paper's contribution — 4-bit quantized optimizer states.

Quantization (mappings/normalization/packing/quantizer) + the compressed
optimizer family (Alg. 1 framework, 4-bit AdamW, 4-bit Factor, baselines).
"""

from repro.core.quantizer import (
    B128_DE,
    B128_DE0,
    B2048_DE,
    RANK1_LINEAR,
    QuantConfig,
    QuantizedTensor,
    dequantize,
    quantize,
)

__all__ = [
    "QuantConfig",
    "QuantizedTensor",
    "quantize",
    "dequantize",
    "B128_DE",
    "B128_DE0",
    "B2048_DE",
    "RANK1_LINEAR",
]
