"""Normalization operators N: scale tensor entries into the unit interval.

Implements the granularities discussed in the paper (Sec. 2.2 / 4.2):

* per-tensor   — one absmax scale for the whole tensor.
* block-wise   — flatten row-major, blocks of size B, absmax per block
                 (B2048 reproduces Dettmers et al.; the paper uses B128).
* rank-1       — per-dim max statistics; per-element scale is the min over
                 dims (App. G, Alg. 4). Falls back to per-tensor for 1-d.

All operators are signed-safe: N(x) = sign(x) * N(|x|) (App. E.1), i.e. we
normalize by absolute-value statistics and keep the sign. Every operator
returns ``(normalized, scales)`` and has a matching ``*_denorm`` that maps the
stored scales back to a per-element scale array.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

__all__ = [
    "pertensor_normalize",
    "pertensor_denorm",
    "blockwise_normalize",
    "blockwise_denorm",
    "rank1_normalize",
    "rank1_denorm",
    "blockwise_num_blocks",
]

_EPS = 1e-12


def _guard(s: jnp.ndarray) -> jnp.ndarray:
    """Avoid division by zero for all-zero tensors/blocks/rows."""
    return jnp.where(s > 0, s, jnp.ones_like(s))


# ---------------------------------------------------------------------------
# per-tensor
# ---------------------------------------------------------------------------


def pertensor_normalize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    s = _guard(jnp.max(jnp.abs(x)))
    return x / s, s[None]  # scales shape (1,)


def pertensor_denorm(scales: jnp.ndarray, shape: Tuple[int, ...]) -> jnp.ndarray:
    return jnp.broadcast_to(scales[0], shape)


# ---------------------------------------------------------------------------
# block-wise
# ---------------------------------------------------------------------------


def blockwise_num_blocks(size: int, block: int) -> int:
    return -(-size // block)


def blockwise_normalize(
    x: jnp.ndarray, block: int
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Row-major flattened block-wise absmax normalization.

    Returns (normalized (same shape as x), scales (num_blocks,)).
    """
    flat = x.reshape(-1)
    n = flat.shape[0]
    nb = blockwise_num_blocks(n, block)
    pad = nb * block - n
    padded = jnp.pad(flat, (0, pad))
    blocks = padded.reshape(nb, block)
    s = _guard(jnp.max(jnp.abs(blocks), axis=1))  # (nb,)
    normed = (blocks / s[:, None]).reshape(-1)[:n].reshape(x.shape)
    return normed, s


def blockwise_denorm(
    scales: jnp.ndarray, shape: Tuple[int, ...], block: int
) -> jnp.ndarray:
    """Per-element scale array from block scales."""
    n = 1
    for d in shape:
        n *= d
    per_elem = jnp.repeat(scales, block)[:n]
    return per_elem.reshape(shape)


# ---------------------------------------------------------------------------
# rank-1 (App. G)
# ---------------------------------------------------------------------------


def rank1_normalize(x: jnp.ndarray) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, ...]]:
    """Rank-1 normalization: per-dim absmax statistics, elementwise min.

    For x of rank p, stats[r] has shape (x.shape[r],): the absmax over all
    other dims. The per-element scale is min_r stats[r][i_r]. Rank-1 on a 1-d
    tensor degenerates to per-tensor... no: for 1-d the per-dim stat IS |x|
    itself, which would make every element its own scale; following the paper
    we treat 1-d as per-tensor.
    """
    if x.ndim <= 1:
        normed, s = pertensor_normalize(x)
        return normed, (s,)
    a = jnp.abs(x)
    stats = []
    for r in range(x.ndim):
        axes = tuple(i for i in range(x.ndim) if i != r)
        stats.append(jnp.max(a, axis=axes))  # (d_r,)
    scale = rank1_denorm(tuple(stats), x.shape)
    return x / scale, tuple(stats)


def rank1_denorm(
    stats: Tuple[jnp.ndarray, ...], shape: Tuple[int, ...]
) -> jnp.ndarray:
    """Per-element scale = min over dims of broadcast per-dim statistics."""
    if len(shape) <= 1:
        return jnp.broadcast_to(_guard(stats[0][0]), shape)
    scale = None
    for r, stat in enumerate(stats):
        view = [1] * len(shape)
        view[r] = shape[r]
        b = stat.reshape(view)
        scale = b if scale is None else jnp.minimum(scale, b)
    return _guard(jnp.broadcast_to(scale, shape))
