"""repro: Memory Efficient Optimizers with 4-bit States (NeurIPS 2023) —
production-grade JAX/TPU framework reproduction."""

__version__ = "1.0.0"
