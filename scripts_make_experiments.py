"""Generate EXPERIMENTS.md §Dry-run + §Roofline tables from the result JSONs
(static sections — validation + §Perf — live in the template below)."""

import json
import os

GB = 1e9


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/GB:.2f}"


def dryrun_tables():
    rows = json.load(open("results/dryrun.json"))
    out = []
    for mesh in ("single", "multi"):
        out.append(f"\n### Mesh: {mesh} "
                   f"({'16x16 = 256 chips (data, model)' if mesh=='single' else '2x16x16 = 512 chips (pod, data, model)'})\n")
        out.append("| arch | shape | status | compile_s | args GB/dev | temps GB/dev | coll ops (module) |")
        out.append("|---|---|---|---|---|---|---|")
        for r in rows:
            if r.get("mesh", "single") != mesh and r["status"] != "skipped":
                continue
            if r["status"] == "skipped":
                if mesh == "single":
                    out.append(f"| {r['arch']} | {r['shape']} | SKIP: {r['reason'][:60]} | - | - | - | - |")
                continue
            m = r.get("memory", {})
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['status']} | {r.get('compile_s','-')} "
                f"| {fmt_bytes(m.get('argument_bytes'))} | {fmt_bytes(m.get('temp_bytes'))} "
                f"| {r.get('collectives',{}).get('ops','-'):.0f} |"
            )
    return "\n".join(out)


def roofline_table():
    """Measured (decomposed-compile) rows preferred; any cell the probe sweep
    has not reached yet falls back to the module-level terms from the
    dry-run (flagged: scan bodies counted once -> lower bound)."""
    measured = (
        json.load(open("results/roofline.json"))
        if os.path.exists("results/roofline.json")
        else []
    )
    have = {(r["arch"], r["shape"]) for r in measured}
    rows = list(measured)
    for r in json.load(open("results/dryrun.json")):
        if r.get("mesh") != "single" or r.get("status") != "ok":
            continue
        if (r["arch"], r["shape"]) in have:
            continue
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "status": "ok",
            "roofline": r["roofline"], "module_level": True,
        })
    out = [
        "| arch | shape | compute_s | memory_s | collective_s | bottleneck | MODEL_FLOPS | useful ratio | what would move the dominant term |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    HINTS = {
        ("memory", "train"): "fewer fp32 round-trips in the update path / larger microbatch to amortize weight traffic",
        ("memory", "prefill"): "larger attention tiles so weights+KV stream once per tile",
        ("memory", "decode"): "decode is weight-streaming; batch growth amortizes weight reads",
        ("collective", "train"): "cut TP all-reduces (sequence-parallel layout) or overlap with compute",
        ("collective", "prefill"): "overlap TP collectives with per-chunk attention compute",
        ("collective", "decode"): "replicate small kv projections; batch more tokens per gather",
        ("compute", "train"): "already compute-bound: raise MFU via larger matmul tiles",
        ("compute", "prefill"): "already compute-bound: fuse attention chains",
        ("compute", "decode"): "already compute-bound (unusual for decode)",
    }
    for r in rows:
        if r.get("status") == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | SKIP | - | - | {r['reason'][:48]} |")
            continue
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | ERROR | - | - | {r.get('error','')[:48]} |")
            continue
        t = r["roofline"]
        shape_kind = ("train" if "train" in r["shape"] else
                      "prefill" if "prefill" in r["shape"] else "decode")
        hint = HINTS.get((t["bottleneck"], shape_kind), "")
        flag = " †" if r.get("module_level") else ""
        out.append(
            f"| {r['arch']} | {r['shape']}{flag} | {t['compute_s']:.4f} | {t['memory_s']:.4f} "
            f"| {t['collective_s']:.4f} | **{t['bottleneck']}** | {t['model_flops_total']:.3g} "
            f"| {t['useful_ratio']:.3f} | {hint} |"
        )
    out.append(
        "\n† module-level terms from the full-step compile (scan bodies "
        "counted once — lower bounds); all other rows are decomposed-compile "
        "measurements."
    )
    return "\n".join(out)


HEADER = open("EXPERIMENTS_template.md").read() if os.path.exists("EXPERIMENTS_template.md") else ""


def main():
    tmpl = open("EXPERIMENTS_template.md").read()
    tmpl = tmpl.replace("{{DRYRUN_TABLES}}", dryrun_tables())
    tmpl = tmpl.replace("{{ROOFLINE_TABLE}}", roofline_table())
    open("EXPERIMENTS.md", "w").write(tmpl)
    print("EXPERIMENTS.md written,", len(tmpl), "chars")


if __name__ == "__main__":
    main()
