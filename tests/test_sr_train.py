"""Stochastic rounding at the *train-step* level (not just the quantizer).

Step 1 of a fresh run compresses the first moments with SR; step 2 consumes
the dequantized states — so after two steps the params carry exactly one
round of quantization noise.  Averaging the 2-step params over many base
keys must converge to the rounding-free (fp32-state) trajectory: SR is
unbiased (Alg. 1 / Assumption 4), so the mean bias shrinks like 1/sqrt(N)
while a single run's deviation does not.

Also enforced: the key actually reaches the quantizer through the whole
``TrainState -> build_train_step -> compressed()`` stack (different keys =>
different packed codes), and the stream is deterministic (same key =>
bit-exact replay).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.optimizers import make_optimizer
from repro.core.quantizer import QuantizedTensor
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import LayerSpec, ModelConfig, init_model
from repro.train.train_loop import build_train_step, make_train_state

jax.config.update("jax_platform_name", "cpu")

CFG = ModelConfig(
    name="sr-lm",
    num_layers=1,
    d_model=64,
    num_heads=2,
    num_kv_heads=1,
    head_dim=32,
    d_ff=128,
    vocab_size=256,
    blocks=(LayerSpec("dense", 0),),
    remat=False,
)

_DATA = SyntheticLM(DataConfig(CFG.vocab_size, 16, 8, seed=4))


def _batch(t):
    return {k: jnp.asarray(v) for k, v in _DATA.batch_at(t).items()}


_STEP_CACHE = {}


def _run_two_steps(opt, params, key, cache_key):
    # one compile per distinct optimizer config — the 48-key sweep reuses it
    if cache_key not in _STEP_CACHE:
        _STEP_CACHE[cache_key] = jax.jit(build_train_step(CFG, opt))
    step_fn = _STEP_CACHE[cache_key]
    state = make_train_state(params, opt, key=key)
    for t in range(2):
        state, _ = step_fn(state, _batch(t))
    return state


@pytest.fixture(scope="module")
def sr_runs():
    """(params, fp32-reference embed, SR embeds over N keys, RTN embed)."""
    params, _ = init_model(jax.random.PRNGKey(0), CFG)
    # reference: identical chain with raw fp32 momentum (rounding-free)
    ref = _run_two_steps(make_optimizer("sgdm", 5e-2), params, None, "sgdm")
    opt_sr = make_optimizer("sgdm4bit", 5e-2)
    embeds = [
        np.asarray(
            _run_two_steps(
                opt_sr, params, jax.random.PRNGKey(i), "sgdm4bit_sr"
            ).params["embed"]
        )
        for i in range(48)
    ]
    rtn = _run_two_steps(
        make_optimizer("sgdm4bit", 5e-2, stochastic_rounding=False),
        params, None, "sgdm4bit_rtn",
    )
    return params, np.asarray(ref.params["embed"]), embeds, np.asarray(
        rtn.params["embed"]
    )


def test_sr_mean_update_converges_to_rounding_free(sr_runs):
    _, ref, embeds, _ = sr_runs
    single_dev = float(np.mean([np.abs(e - ref).mean() for e in embeds]))
    assert single_dev > 0, "SR produced no quantization noise — key not plumbed?"
    mean_bias = float(np.abs(np.mean(embeds, axis=0) - ref).mean())
    # unbiased => averaging 48 keys shrinks the error ~7x; 0.3 leaves slack
    assert mean_bias < 0.3 * single_dev, (mean_bias, single_dev)


def test_sr_mean_beats_round_to_nearest(sr_runs):
    """RTN carries a systematic rounding bias the SR average does not."""
    _, ref, embeds, rtn = sr_runs
    mean_bias = float(np.abs(np.mean(embeds, axis=0) - ref).mean())
    rtn_bias = float(np.abs(rtn - ref).mean())
    assert mean_bias < rtn_bias, (mean_bias, rtn_bias)


def test_sr_keys_decorrelate_and_reproduce(sr_runs):
    params = sr_runs[0]
    opt = make_optimizer("adamw4bit", 3e-3, stochastic_rounding=True)

    s_a = _run_two_steps(opt, params, jax.random.PRNGKey(0), "adamw4bit_sr")
    s_b = _run_two_steps(opt, params, jax.random.PRNGKey(1), "adamw4bit_sr")
    s_a2 = _run_two_steps(opt, params, jax.random.PRNGKey(0), "adamw4bit_sr")

    m_a = s_a.opt_state["m"]["embed"]
    m_b = s_b.opt_state["m"]["embed"]
    assert isinstance(m_a, QuantizedTensor)
    # different base keys -> different SR noise in the packed codes
    assert not np.array_equal(np.asarray(m_a.codes), np.asarray(m_b.codes))
    # same base key -> the entire TrainState replays bit-exactly
    for x, y in zip(
        jax.tree_util.tree_leaves(s_a), jax.tree_util.tree_leaves(s_a2)
    ):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_sr_noop_without_key():
    """No key in TrainState => deterministic RTN fallback (two SR-configured
    runs without keys are bit-identical)."""
    params, _ = init_model(jax.random.PRNGKey(0), CFG)
    opt = make_optimizer("adamw4bit", 3e-3, stochastic_rounding=True)
    a = _run_two_steps(opt, params, None, "adamw4bit_sr_nokey")
    b = _run_two_steps(opt, params, None, "adamw4bit_sr_nokey")
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
