"""Stochastic rounding at the *train-step* level (not just the quantizer).

Step 1 of a fresh run compresses the first moments with SR; step 2 consumes
the dequantized states — so after two steps the params carry exactly one
round of quantization noise.  Averaging the 2-step params over many base
keys must converge to the rounding-free (fp32-state) trajectory: SR is
unbiased (Alg. 1 / Assumption 4), so the mean bias shrinks like 1/sqrt(N)
while a single run's deviation does not.

Also enforced: the key actually reaches the quantizer through the whole
``TrainState -> build_train_step -> compressed()`` stack (different keys =>
different packed codes), and the stream is deterministic (same key =>
bit-exact replay).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.optimizers import make_optimizer
from repro.core.quantizer import QuantizedTensor
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import LayerSpec, ModelConfig, init_model
from repro.train.train_loop import build_train_step, make_train_state

jax.config.update("jax_platform_name", "cpu")

CFG = ModelConfig(
    name="sr-lm",
    num_layers=1,
    d_model=64,
    num_heads=2,
    num_kv_heads=1,
    head_dim=32,
    d_ff=128,
    vocab_size=256,
    blocks=(LayerSpec("dense", 0),),
    remat=False,
)

_DATA = SyntheticLM(DataConfig(CFG.vocab_size, 16, 8, seed=4))


def _batch(t):
    return {k: jnp.asarray(v) for k, v in _DATA.batch_at(t).items()}


_STEP_CACHE = {}


def _run_two_steps(opt, params, key, cache_key):
    # one compile per distinct optimizer config — the 48-key sweep reuses it
    if cache_key not in _STEP_CACHE:
        _STEP_CACHE[cache_key] = jax.jit(build_train_step(CFG, opt))
    step_fn = _STEP_CACHE[cache_key]
    state = make_train_state(params, opt, key=key)
    for t in range(2):
        state, _ = step_fn(state, _batch(t))
    return state


@pytest.fixture(scope="module")
def sr_runs():
    """(params, fp32-reference embed, SR embeds over N keys, RTN embed)."""
    params, _ = init_model(jax.random.PRNGKey(0), CFG)
    # reference: identical chain with raw fp32 momentum (rounding-free)
    ref = _run_two_steps(make_optimizer("sgdm", 5e-2), params, None, "sgdm")
    opt_sr = make_optimizer("sgdm4bit", 5e-2)
    embeds = [
        np.asarray(
            _run_two_steps(
                opt_sr, params, jax.random.PRNGKey(i), "sgdm4bit_sr"
            ).params["embed"]
        )
        for i in range(48)
    ]
    rtn = _run_two_steps(
        make_optimizer("sgdm4bit", 5e-2, stochastic_rounding=False),
        params, None, "sgdm4bit_rtn",
    )
    return params, np.asarray(ref.params["embed"]), embeds, np.asarray(
        rtn.params["embed"]
    )


def test_sr_mean_update_converges_to_rounding_free(sr_runs):
    _, ref, embeds, _ = sr_runs
    single_dev = float(np.mean([np.abs(e - ref).mean() for e in embeds]))
    assert single_dev > 0, "SR produced no quantization noise — key not plumbed?"
    mean_bias = float(np.abs(np.mean(embeds, axis=0) - ref).mean())
    # unbiased => averaging 48 keys shrinks the error ~7x; 0.3 leaves slack
    assert mean_bias < 0.3 * single_dev, (mean_bias, single_dev)


def test_sr_mean_beats_round_to_nearest(sr_runs):
    """RTN carries a systematic rounding bias the SR average does not."""
    _, ref, embeds, rtn = sr_runs
    mean_bias = float(np.abs(np.mean(embeds, axis=0) - ref).mean())
    rtn_bias = float(np.abs(rtn - ref).mean())
    assert mean_bias < rtn_bias, (mean_bias, rtn_bias)


def test_sr_keys_decorrelate_and_reproduce(sr_runs):
    params = sr_runs[0]
    opt = make_optimizer("adamw4bit", 3e-3, stochastic_rounding=True)

    s_a = _run_two_steps(opt, params, jax.random.PRNGKey(0), "adamw4bit_sr")
    s_b = _run_two_steps(opt, params, jax.random.PRNGKey(1), "adamw4bit_sr")
    s_a2 = _run_two_steps(opt, params, jax.random.PRNGKey(0), "adamw4bit_sr")

    m_a = s_a.opt_state["m"]["embed"]
    m_b = s_b.opt_state["m"]["embed"]
    assert isinstance(m_a, QuantizedTensor)
    # different base keys -> different SR noise in the packed codes
    assert not np.array_equal(np.asarray(m_a.codes), np.asarray(m_b.codes))
    # same base key -> the entire TrainState replays bit-exactly
    for x, y in zip(
        jax.tree_util.tree_leaves(s_a), jax.tree_util.tree_leaves(s_a2)
    ):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# fused-kernel SR route at the train-step level
# ---------------------------------------------------------------------------

# d_ff=256 makes the mlp w1/w3 leaves (1, 64, 256) kernel-eligible (last dim a
# multiple of 256, > 4096 elements); attention/embed leaves stay unfused, so a
# step exercises both routes side by side.
KCFG = ModelConfig(
    name="sr-kernel-lm",
    num_layers=1,
    d_model=64,
    num_heads=2,
    num_kv_heads=1,
    head_dim=32,
    d_ff=256,
    vocab_size=256,
    blocks=(LayerSpec("dense", 0),),
    remat=False,
)


def _mlp_leaf(state):
    return np.asarray(state.params["decoder"][0]["sub0"]["mlp"]["w1"])


def _run_two_steps_cfg(opt, params, key, cache_key, cfg):
    if cache_key not in _STEP_CACHE:
        _STEP_CACHE[cache_key] = jax.jit(build_train_step(cfg, opt))
    step_fn = _STEP_CACHE[cache_key]
    state = make_train_state(params, opt, key=key)
    for t in range(2):
        state, _ = step_fn(state, _batch(t))
    return state


def test_kernel_route_sr_statistically_matches_unfused(monkeypatch):
    """Training through the fused SR kernel route must agree with the unfused
    compressed() SR path in distribution: the two mean trajectories (over N
    base keys) coincide much more tightly than single runs scatter, on a
    kernel-eligible leaf."""
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "ref")
    params, _ = init_model(jax.random.PRNGKey(0), KCFG)
    n_keys = 16

    def sweep(use_kernel):
        opt = make_optimizer(
            "adamw4bit", 3e-3, stochastic_rounding=True, use_kernel=use_kernel
        )
        tag = f"adamw4bit_sr_k{int(use_kernel)}"
        return [
            _mlp_leaf(
                _run_two_steps_cfg(opt, params, jax.random.PRNGKey(i), tag, KCFG)
            )
            for i in range(n_keys)
        ]

    fused = sweep(True)
    unfused = sweep(False)
    scatter = float(np.mean([np.abs(e - fused[0]).mean() for e in fused[1:]]))
    assert scatter > 0, "kernel-route SR produced no noise — key not plumbed?"
    gap = float(np.abs(np.mean(fused, axis=0) - np.mean(unfused, axis=0)).mean())
    assert gap < 0.5 * scatter, (gap, scatter)


def test_kernel_route_sr_decorrelates_and_replays(monkeypatch):
    """Fused-route SR noise: different base keys produce different packed
    codes; the same base key replays the whole TrainState bit-exactly."""
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "ref")
    params, _ = init_model(jax.random.PRNGKey(0), KCFG)
    opt = make_optimizer("production4bit", 3e-3)
    tag = "production4bit_kernel"

    s_a = _run_two_steps_cfg(opt, params, jax.random.PRNGKey(0), tag, KCFG)
    s_b = _run_two_steps_cfg(opt, params, jax.random.PRNGKey(1), tag, KCFG)
    s_a2 = _run_two_steps_cfg(opt, params, jax.random.PRNGKey(0), tag, KCFG)

    m_4bit = s_a.opt_state.states["4bit"]["m"]
    m_leaf = m_4bit["decoder"][0]["sub0"]["mlp"]["w1"]
    assert isinstance(m_leaf, QuantizedTensor)
    m_leaf_b = s_b.opt_state.states["4bit"]["m"]["decoder"][0]["sub0"]["mlp"]["w1"]
    assert not np.array_equal(np.asarray(m_leaf.codes), np.asarray(m_leaf_b.codes))
    for x, y in zip(
        jax.tree_util.tree_leaves(s_a), jax.tree_util.tree_leaves(s_a2)
    ):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_sr_noop_without_key():
    """No key in TrainState => deterministic RTN fallback (two SR-configured
    runs without keys are bit-identical)."""
    params, _ = init_model(jax.random.PRNGKey(0), CFG)
    opt = make_optimizer("adamw4bit", 3e-3, stochastic_rounding=True)
    a = _run_two_steps(opt, params, None, "adamw4bit_sr_nokey")
    b = _run_two_steps(opt, params, None, "adamw4bit_sr_nokey")
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
