"""partition() label-routing edge cases + sharding of partitioned states.

Covers the production-preset failure modes: a param added after init must
raise (KeyError — not silently train with missing state), empty partitions
must be legal (a label no leaf maps to), and ``opt_state_shardings`` must
mirror a ``PartitionState`` on a real multi-device mesh — quantized body
leaves sharded like their params (+ZeRO), masked positions preserved.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding

from repro.core.optimizers import (
    QuantPolicy,
    as_optimizer,
    label_by_regex,
    make_optimizer,
    partition,
    production4bit,
)
from repro.core.optimizers.adamw import M_4BIT, V_4BIT, adamw_chain
from repro.core.optimizers.transform import MaskedNode, PartitionState
from repro.core.quantizer import QuantizedTensor
from repro.sharding.specs import opt_state_shardings

jax.config.update("jax_platform_name", "cpu")


def _params():
    rng = np.random.default_rng(0)
    f32 = lambda a: jnp.asarray(a.astype(np.float32))
    return {
        "embed": f32(rng.normal(size=(64, 256)) * 0.1),
        "body": f32(rng.normal(size=(16, 512)) * 0.1),
        "bias": f32(rng.normal(size=(64,)) * 0.1),
    }


def _grads(params, t=0):
    rng = np.random.default_rng(50 + t)
    return jax.tree_util.tree_map(
        lambda p: jnp.asarray(rng.normal(size=p.shape).astype(np.float32) * 0.02),
        params,
    )


def _prod_tx():
    return partition(
        {
            "fp32": adamw_chain(1e-3),
            "4bit": adamw_chain(
                1e-3,
                m_policy=QuantPolicy(config=M_4BIT),
                v_policy=QuantPolicy(config=V_4BIT),
            ),
        },
        label_by_regex(("embed", "bias"), "fp32", "4bit"),
    )


def test_param_added_after_init_raises_keyerror():
    tx = _prod_tx()
    params = _params()
    state = tx.init(params)
    grown = dict(params, new_adapter=jnp.zeros((8, 512), jnp.float32))
    with pytest.raises(KeyError, match="new_adapter"):
        tx.update(_grads(grown), state, grown)


def test_param_removed_after_init_raises_keyerror():
    tx = _prod_tx()
    params = _params()
    state = tx.init(params)
    shrunk = {k: v for k, v in params.items() if k != "body"}
    with pytest.raises(KeyError, match="body"):
        tx.update(_grads(shrunk), state, shrunk)


def test_empty_partition_is_legal():
    """A transform whose label matches no leaf must init and update cleanly
    (e.g. a preset whose fp32 patterns miss a headless model)."""
    tx = partition(
        {
            "a": adamw_chain(1e-3),
            "unused": adamw_chain(1e-3),
        },
        lambda path, p: "a",
    )
    params = _params()
    state = tx.init(params)
    assert jax.tree_util.tree_leaves(state.states["unused"]) != []  # counts remain
    u, state2 = tx.update(_grads(params), state, params)
    assert len(jax.tree_util.tree_leaves(u)) == len(jax.tree_util.tree_leaves(params))
    # masked placeholders stayed placeholders
    assert isinstance(state2, PartitionState)


def test_partition_state_roundtrips_tree_ops():
    """PartitionState (keyed pytree with static label/path aux) must survive
    tree_map + eval_shape with structure intact (jit in_shardings needs it)."""
    tx = _prod_tx()
    params = _params()
    state = tx.init(params)
    mapped = jax.tree_util.tree_map(lambda x: x, state)
    assert jax.tree_util.tree_structure(mapped) == jax.tree_util.tree_structure(state)
    s_shape = jax.eval_shape(lambda: tx.init(params))
    assert jax.tree_util.tree_structure(s_shape) == jax.tree_util.tree_structure(state)
    assert mapped.param_paths == state.param_paths


def test_opt_state_shardings_partitioned_state_on_8dev_mesh():
    """On a real (2, 4) host mesh: quantized body codes shard like the param
    (+ZeRO over data), fp32-partition moments shard too, masked positions are
    preserved, and the sharding tree structure matches the state exactly."""
    assert jax.device_count() >= 8, "conftest should force 8 host devices"
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    params = _params()
    axes = {
        "embed": ("vocab", "embed"),
        "body": ("heads", "mlp"),
        "bias": ("embed",),
    }
    opt = production4bit(1e-3, fp32_patterns=("embed", "bias"))
    state = opt.init(params)
    sh = opt_state_shardings(state, params, axes, mesh, zero=True)

    assert jax.tree_util.tree_structure(sh) == jax.tree_util.tree_structure(state)
    assert all(isinstance(l, NamedSharding) for l in jax.tree_util.tree_leaves(sh))

    # 4-bit partition: body momentum is quantized; its codes must NOT be
    # fully replicated (param spec + ZeRO survives into the codes sharding)
    m_4bit = sh.states["4bit"].states[0].inner.m
    assert isinstance(state.states["4bit"].states[0].inner.m["body"], QuantizedTensor)
    codes_spec = m_4bit["body"].codes.spec
    assert any(e is not None for e in codes_spec), codes_spec
    # masked position: the embed leaf belongs to the fp32 partition
    assert isinstance(m_4bit["embed"], MaskedNode)
    # fp32 partition: raw embed moment sharded (not replicated) under ZeRO
    m_fp32 = sh.states["fp32"].states[0].inner.m
    assert any(e is not None for e in m_fp32["embed"].spec), m_fp32["embed"].spec


def test_production4bit_jits_on_mesh():
    """The preset's update must lower under jit with sharded inputs."""
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    params = _params()
    opt = production4bit(1e-3)
    state = opt.init(params)
    g = _grads(params)
    p1, s1 = opt.update(g, state, params, key=jax.random.PRNGKey(0))
    with mesh:
        p2, s2 = jax.jit(opt.update, static_argnames=())(
            g, state, params, key=jax.random.PRNGKey(0)
        )
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7)


def test_partition_labels_cached_per_treedef():
    """Label resolution (path building + regex/label-fn calls) must run once
    per param tree layout, not once per update step (ROADMAP perf item)."""
    calls = []

    def lab(path, leaf):
        calls.append(path)
        return "fp32" if "embed" in path or "bias" in path else "4bit"

    tx = partition(
        {
            "fp32": adamw_chain(1e-3),
            "4bit": adamw_chain(
                1e-3,
                m_policy=QuantPolicy(config=M_4BIT),
                v_policy=QuantPolicy(config=V_4BIT),
            ),
        },
        lab,
    )
    params = _params()
    n_leaves = len(jax.tree_util.tree_leaves(params))
    state = tx.init(params)
    assert len(calls) == n_leaves  # one labelling pass at init
    g = _grads(params)
    _, state = tx.update(g, state, params)
    _, state = tx.update(_grads(params, 1), state, params)
    assert len(calls) == n_leaves, "labels recomputed on steady-state update"

    # a *different* layout is a cache miss (one fresh labelling pass) and
    # still trips the param-drift guard against the stale state
    grown = dict(params, extra=jnp.zeros((8, 512), jnp.float32))
    with pytest.raises(KeyError, match="extra"):
        tx.update(_grads(grown), state, grown)
    assert len(calls) == 2 * n_leaves + 1


def test_make_optimizer_production4bit_overrides():
    opt = make_optimizer("production4bit", 1e-3, weight_decay=0.1,
                         stochastic_rounding=False)
    assert opt.name == "production4bit"
    with pytest.raises(ValueError, match="does not accept"):
        make_optimizer("production4bit", 1e-3, exclude_embeddings=True)
