"""Mapping-registry contract (ISSUE 10).

Every map reaches ``QuantConfig`` through ``mappings.register_mapping`` —
including the paper's three.  These tests pin:

* the table contract for EVERY registered map (sorted, unique, finite,
  length <= 2^bits, encode/decode round-trips bit-exactly, odd symmetry
  when the spec declares it),
* bit-identical ``linear``/``de``/``de0`` tables pre/post the registry
  refactor (frozen 4-bit golden values),
* construction-time validation with did-you-mean for ``QuantConfig`` and
  ``make_optimizer`` overrides,
* registration hygiene (duplicate rejection; registered maps usable
  end-to-end through ``quantize``/``dequantize``).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mappings
from repro.core.quantizer import QuantConfig, dequantize, quantize

jax.config.update("jax_platform_name", "cpu")

LEGACY = ("linear", "de", "de0")
NEW = ("dynamic", "quantile", "log-ema")


# ---------------------------------------------------------------------------
# registry surface
# ---------------------------------------------------------------------------


def test_legacy_and_new_maps_registered():
    names = mappings.registered()
    for n in LEGACY + NEW:
        assert n in names, n


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError, match="already registered"):
        mappings.register_mapping("de", lambda bits, signed: np.array([0.5]))


def test_unknown_mapping_lists_registry_and_suggests():
    with pytest.raises(ValueError) as e:
        mappings.get_spec("dynamik")
    msg = str(e.value)
    for n in mappings.registered():
        assert n in msg  # the error lists mappings.registered()
    assert "did you mean 'dynamic'" in msg


def test_quantconfig_validates_mapping_at_construction():
    with pytest.raises(ValueError, match="registered mappings"):
        QuantConfig(mapping="liner")
    with pytest.raises(ValueError, match="did you mean 'linear'"):
        QuantConfig(mapping="liner")
    # every registered map constructs, displays, and tables
    for name in mappings.registered():
        cfg = QuantConfig(mapping=name)
        assert mappings.get_spec(name).display in cfg.name
        assert cfg.table().shape[0] <= 2**cfg.bits


def test_make_optimizer_did_you_mean():
    from repro.core.optimizers import make_optimizer

    with pytest.raises(ValueError, match="did you mean 'shampoo4bit'"):
        make_optimizer("shampoo4bits", 1e-3)
    with pytest.raises(ValueError, match="did you mean 'precond_every'"):
        make_optimizer("shampoo4bit", 1e-3, precond_evry=5)
    with pytest.raises(ValueError, match="did you mean 'weight_decay'"):
        make_optimizer("adamw4bit", 1e-3, weight_dekay=0.1)


# ---------------------------------------------------------------------------
# the table contract, for every registered map
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", mappings.registered())
@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("signed", [True, False])
def test_table_contract(kind, bits, signed):
    t = np.asarray(mappings.mapping_table(kind, bits, signed))
    assert t.ndim == 1 and 1 <= t.size <= 2**bits
    assert np.all(np.isfinite(t))
    assert np.all(np.diff(t) > 0)  # sorted AND unique
    assert t.dtype == np.float32
    lo = -1.0 if signed else 0.0
    assert t.min() >= lo and t.max() <= 1.0


@pytest.mark.parametrize("kind", mappings.registered())
@pytest.mark.parametrize("signed", [True, False])
def test_symmetry_matches_declaration(kind, signed):
    spec = mappings.get_spec(kind)
    t = np.asarray(mappings.mapping_table(kind, 4, True))
    if spec.symmetric_signed:
        np.testing.assert_array_equal(t, -t[::-1])
    else:
        assert not np.array_equal(t, -t[::-1])  # de/de0: +1.0 has no twin


@pytest.mark.parametrize("kind", mappings.registered())
@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("signed", [True, False])
def test_encode_decode_roundtrip_bitexact(kind, bits, signed):
    # decoding every code then re-encoding must reproduce the codes exactly
    t = mappings.mapping_table(kind, bits, signed)
    codes = jnp.arange(t.shape[0], dtype=jnp.uint8)
    vals = mappings.decode(codes, t)
    np.testing.assert_array_equal(np.asarray(mappings.encode(vals, t)), np.asarray(codes))


# ---------------------------------------------------------------------------
# frozen pre-refactor goldens: the registry refactor must not move a bit
# ---------------------------------------------------------------------------

GOLDEN_4BIT = {
    ("linear", True): [-1.0, -0.875, -0.75, -0.625, -0.5, -0.375, -0.25, -0.125, 0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.875, 1.0],
    ("linear", False): [0.0625, 0.125, 0.1875, 0.25, 0.3125, 0.375, 0.4375, 0.5, 0.5625, 0.625, 0.6875, 0.75, 0.8125, 0.875, 0.9375, 1.0],
    ("de", True): [-0.887499988079071, -0.6625000238418579, -0.4375, -0.21250000596046448, -0.07750000059604645, -0.032499998807907104, -0.005499999970197678, 0.0, 0.005499999970197678, 0.032499998807907104, 0.07750000059604645, 0.21250000596046448, 0.4375, 0.6625000238418579, 0.887499988079071, 1.0],
    ("de", False): [0.0, 0.0032500000670552254, 0.00774999987334013, 0.021250000223517418, 0.04374999925494194, 0.06624999642372131, 0.08874999731779099, 0.15625, 0.26875001192092896, 0.3812499940395355, 0.4937500059604645, 0.606249988079071, 0.71875, 0.831250011920929, 0.9437500238418579, 1.0],
    ("de0", True): [-0.887499988079071, -0.6625000238418579, -0.4375, -0.21250000596046448, -0.07750000059604645, -0.032499998807907104, -0.005499999970197678, 0.005499999970197678, 0.032499998807907104, 0.07750000059604645, 0.21250000596046448, 0.4375, 0.6625000238418579, 0.887499988079071, 1.0],
    ("de0", False): [0.0032500000670552254, 0.00774999987334013, 0.021250000223517418, 0.04374999925494194, 0.06624999642372131, 0.08874999731779099, 0.15625, 0.26875001192092896, 0.3812499940395355, 0.4937500059604645, 0.606249988079071, 0.71875, 0.831250011920929, 0.9437500238418579, 1.0],
}


@pytest.mark.parametrize("kind,signed", sorted(GOLDEN_4BIT, key=str))
def test_legacy_tables_bit_identical_post_refactor(kind, signed):
    t = np.asarray(mappings.mapping_table(kind, 4, signed))
    golden = np.array(GOLDEN_4BIT[(kind, signed)], np.float32)
    np.testing.assert_array_equal(t, golden)


# ---------------------------------------------------------------------------
# map-specific properties the docs table advertises
# ---------------------------------------------------------------------------


def test_dynamic_signed_symmetric_with_unit_endpoints():
    t = np.asarray(mappings.mapping_table("dynamic", 4, True))
    assert -1.0 in t and 1.0 in t and 0.0 in t
    # de's asymmetry (the motivating defect for factors) — pinned here
    de = np.asarray(mappings.mapping_table("de", 4, True))
    assert 1.0 in de and -1.0 not in de


def test_quantile_and_log_ema_unsigned_exclude_zero():
    for kind in ("quantile", "log-ema"):
        t = np.asarray(mappings.mapping_table(kind, 4, False))
        assert t.min() > 0.0, kind  # zero-excluding, like the linear baseline
        assert t.max() == 1.0, kind


def test_log_ema_is_geometric():
    t = np.asarray(mappings.mapping_table("log-ema", 4, False), np.float64)
    ratios = t[1:] / t[:-1]
    np.testing.assert_allclose(ratios, ratios[0], rtol=1e-5)


# ---------------------------------------------------------------------------
# third-party registration flows end-to-end into quantize/dequantize
# ---------------------------------------------------------------------------


def test_registered_map_flows_through_quantize():
    name = "test-halves"
    if name not in mappings.registered():  # survive pytest re-imports
        mappings.register_mapping(
            name,
            lambda bits, signed: (np.arange(2**bits, dtype=np.float64) + 1) / 2**bits,
            display="Halves",
        )
    cfg = QuantConfig(bits=4, normalization="pertensor", mapping=name, signed=False)
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(0), (64,)))
    xq = dequantize(quantize(x, cfg))
    assert xq.shape == x.shape and bool(jnp.all(jnp.isfinite(xq)))
    assert float(jnp.max(jnp.abs(xq - x))) <= float(jnp.max(jnp.abs(x))) * 0.5
