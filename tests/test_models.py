"""Model correctness: attention/GLA vs naive oracles, decode==train parity."""

import math

import pytest as _pytest

pytestmark = _pytest.mark.slow  # decode-parity sweeps compile whole models

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (
    LayerSpec,
    ModelConfig,
    decode_step,
    forward_hidden,
    init_model,
    init_serve_cache,
    loss_fn,
    plan_scan_units,
)
from repro.models.attention import train_attention
from repro.models.gla import GLAState, gla_chunked, gla_decode_step
from repro.models.layers import COMPUTE_DTYPE

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# chunked attention vs naive oracle
# ---------------------------------------------------------------------------


def naive_attention(q, k, v, causal, window, softcap_val):
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, k.astype(jnp.float32)) / math.sqrt(D)
    if softcap_val > 0:
        s = softcap_val * jnp.tanh(s / softcap_val)
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(k.shape[1])[None, :]
    ok = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        ok &= kp <= qp
    if window > 0:
        ok &= kp > qp - window
    s = jnp.where(ok[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, D)


@pytest.mark.parametrize("causal,window,softcap_val", [
    (True, 0, 0.0), (True, 7, 0.0), (True, 0, 30.0), (False, 0, 0.0),
    (True, 64, 0.0),
])
def test_train_attention_matches_naive(causal, window, softcap_val):
    B, S, Hq, Hkv, D = 2, 50, 4, 2, 16
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, S, Hq, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)).astype(np.float32))
    got = train_attention(
        q, k, v, causal=causal, window=window, softcap_val=softcap_val,
        q_chunk=16, k_chunk=16,
    )
    want = naive_attention(q, k, v, causal, window, softcap_val)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# chunked GLA vs naive recurrence
# ---------------------------------------------------------------------------


def naive_gla(q, k, v, log_a, normalize):
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    Smat = np.zeros((B, H, dk, dv))
    n = np.zeros((B, H, dk))
    ys = []
    q, k, v, log_a = map(lambda x: np.asarray(x, np.float64), (q, k, v, log_a))
    for t in range(S):
        a = np.exp(log_a[:, t])[..., None]
        Smat = a[..., None] * Smat + k[:, t][..., None] * v[:, t][..., None, :]
        n = a * n + k[:, t]
        y = np.einsum("bhk,bhkv->bhv", q[:, t], Smat)
        if normalize:
            d = np.abs(np.einsum("bhk,bhk->bh", q[:, t], n))
            y = y / np.maximum(d, 1.0)[..., None]
        ys.append(y)
    return np.stack(ys, axis=1)


@pytest.mark.parametrize("normalize", [True, False])
@pytest.mark.parametrize("S,chunk", [(37, 8), (64, 16), (16, 16)])
def test_gla_chunked_matches_naive(normalize, S, chunk):
    B, H, dk, dv = 2, 3, 8, 8
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(B, S, H, dk)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, H, dk)).astype(np.float32)) * 0.3
    v = jnp.asarray(rng.normal(size=(B, S, H, dv)).astype(np.float32))
    log_a = jnp.asarray(-np.abs(rng.normal(size=(B, S, H))).astype(np.float32) * 0.2)
    got, st = gla_chunked(q, k, v, log_a, chunk=chunk, normalize=normalize)
    want = naive_gla(q, k, v, log_a, normalize)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3, atol=2e-4)


def test_gla_decode_continues_chunked():
    """Chunked prefill state feeds the single-step decode recurrence."""
    B, S, H, dk, dv = 1, 24, 2, 8, 8
    rng = np.random.default_rng(2)
    mk = lambda *s: jnp.asarray(rng.normal(size=s).astype(np.float32)) * 0.5
    q, k, v = mk(B, S, H, dk), mk(B, S, H, dk), mk(B, S, H, dv)
    log_a = -jnp.abs(mk(B, S, H)) * 0.3
    full, _ = gla_chunked(q, k, v, log_a, chunk=8)
    half, st = gla_chunked(
        q[:, :16], k[:, :16], v[:, :16], log_a[:, :16], chunk=8
    )
    ys = []
    for t in range(16, S):
        y, st = gla_decode_step(
            q[:, t : t + 1], k[:, t : t + 1], v[:, t : t + 1],
            log_a[:, t : t + 1], st,
        )
        ys.append(y)
    got_tail = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(
        np.asarray(got_tail), np.asarray(full[:, 16:]), rtol=2e-3, atol=2e-4
    )


# ---------------------------------------------------------------------------
# decode parity: teacher-forced forward == token-by-token decode
# ---------------------------------------------------------------------------


def _full_logits(params, cfg, batch):
    x, _ = forward_hidden(params, cfg, batch)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum(
        "bsd,dv->bsv", x.astype(COMPUTE_DTYPE), head.astype(COMPUTE_DTYPE)
    ).astype(jnp.float32)
    if cfg.final_softcap > 0:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits


def _decode_all(params, cfg, tokens):
    B, S = tokens.shape
    caches = init_serve_cache(cfg, B, s_max=256)
    outs = []
    for t in range(S):
        pos = jnp.full((B,), t, jnp.int32)
        logits, caches = decode_step(params, cfg, caches, tokens[:, t], pos)
        outs.append(logits)
    return jnp.stack(outs, axis=1)  # (B, S, V)


DECODE_CASES = {
    "dense_gqa": dict(blocks=(LayerSpec("dense", 0),) * 2),
    "swa": dict(blocks=(LayerSpec("dense", 8),) * 2),
    "softcap_sandwich": dict(
        blocks=(LayerSpec("dense", 8), LayerSpec("dense", 0)),
        attn_softcap=30.0, final_softcap=20.0, sandwich_norm=True,
    ),
    "qk_norm": dict(blocks=(LayerSpec("dense", 0),) * 2, qk_norm=True),
    "rope2d": dict(blocks=(LayerSpec("dense", 0),) * 2, rope_variant="rope2d"),
    "moe": dict(
        blocks=(LayerSpec("moe", 0),) * 2, num_experts=4, top_k=2,
        moe_group_size=64,
    ),
    "xlstm": dict(
        blocks=(LayerSpec("mlstm", 0), LayerSpec("slstm", 0)) * 1, gla_chunk=8,
    ),
    "hymba": dict(
        blocks=(LayerSpec("hymba", 8),) * 2, ssm_state=4, gla_chunk=8,
    ),
}


@pytest.mark.parametrize("case", list(DECODE_CASES.keys()))
def test_decode_matches_teacher_forced(case):
    kw = dict(DECODE_CASES[case])
    blocks = kw.pop("blocks")
    cfg = ModelConfig(
        name=case, num_layers=len(blocks), d_model=32, num_heads=4,
        num_kv_heads=2, head_dim=8, d_ff=64, vocab_size=128, blocks=blocks,
        remat=False, **kw,
    )
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, 128)
    full = _full_logits(params, cfg, {"tokens": tokens})
    dec = _decode_all(params, cfg, tokens)
    # MoE routing can differ marginally at capacity edges; others tight.
    tol = 0.08 if case == "moe" else 0.02
    diff = np.max(np.abs(np.asarray(full) - np.asarray(dec)))
    assert diff < tol, f"{case}: max logit diff {diff}"


def test_encdec_decode_parity():
    cfg = ModelConfig(
        name="whisper_tiny", num_layers=2, d_model=32, num_heads=4,
        num_kv_heads=4, head_dim=8, d_ff=64, vocab_size=128,
        blocks=(LayerSpec("dec", 0),) * 2,
        encoder_blocks=(LayerSpec("enc", 0),) * 2,
        family="encdec", norm_type="layernorm", rope_variant="none",
        gated_mlp=False, tie_embeddings=True, remat=False,
    )
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    B, S = 2, 10
    frames = jax.random.normal(jax.random.PRNGKey(2), (B, 16, 32))
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, 128)
    full = _full_logits(params, cfg, {"tokens": tokens, "frames": frames})

    # encoder once, then token-by-token decode
    from repro.models.model import plan_scan_units, _run_units, _final_norm
    from repro.models.layers import sinusoidal_positions

    e = frames.astype(COMPUTE_DTYPE) + sinusoidal_positions(16, 32)[None].astype(COMPUTE_DTYPE)
    e, _, _ = _run_units(cfg, plan_scan_units(cfg.encoder_blocks), params["encoder"], e, positions=None)
    enc_out = _final_norm(cfg, e, params["enc_norm"])

    caches = init_serve_cache(cfg, B, s_max=256)
    outs = []
    for t in range(S):
        pos = jnp.full((B,), t, jnp.int32)
        logits, caches = decode_step(params, cfg, caches, tokens[:, t], pos, enc_out=enc_out)
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    diff = np.max(np.abs(np.asarray(full) - np.asarray(dec)))
    assert diff < 0.02, f"encdec: max logit diff {diff}"


# ---------------------------------------------------------------------------
# scan-unit planning
# ---------------------------------------------------------------------------


def test_plan_scan_units_periodic():
    a, b = LayerSpec("dense", 8), LayerSpec("dense", 0)
    units = plan_scan_units((a, b) * 13)
    assert len(units) == 1 and units[0].repeat == 13 and units[0].pattern == (a, b)


def test_plan_scan_units_runs():
    g, s = LayerSpec("hymba", 0), LayerSpec("hymba", 8)
    layout = (g,) + (s,) * 14 + (g,) + (s,) * 15 + (g,)
    units = plan_scan_units(layout)
    assert [u.repeat for u in units] == [1, 14, 1, 15, 1]


def test_plan_scan_units_uniform():
    d = LayerSpec("dense", 0)
    units = plan_scan_units((d,) * 32)
    assert len(units) == 1 and units[0].repeat == 32
