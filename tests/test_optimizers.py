"""Optimizer tests: exact math, convergence, zero-point reproduction, memory."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.optimizers import (
    FactoredMoment,
    QuantPolicy,
    adafactor,
    adamw32,
    adamw4bit,
    adamw8bit,
    factor4bit,
    quantized_adamw,
    sgdm,
    sgdm4bit,
    sm3,
    state_nbytes,
)
from repro.core.quantizer import QuantConfig, QuantizedTensor

jax.config.update("jax_platform_name", "cpu")


def _params(shape=(16, 512), seed=0):  # 8192 elements: above the 4096 threshold
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=shape).astype(np.float32) * 0.1)}


def _quadratic_loss(params, target):
    return 0.5 * jnp.sum((params["w"] - target) ** 2)


def _run_steps(opt, params, target, steps, key=None):
    state = opt.init(params)
    upd = jax.jit(opt.update)
    losses = []
    for t in range(steps):
        loss, grads = jax.value_and_grad(_quadratic_loss)(params, target)
        k = jax.random.fold_in(key, t) if key is not None else None
        params, state = (upd(grads, state, params, key=k) if k is not None
                         else upd(grads, state, params))
        losses.append(float(loss))
    return params, state, losses


# ---------------------------------------------------------------------------
# exact math: adamw32 equals a hand reference
# ---------------------------------------------------------------------------


def test_adamw32_matches_hand_reference():
    lr, b1, b2, eps, wd = 1e-2, 0.9, 0.999, 1e-8, 0.01
    p0 = np.asarray(_params()["w"], dtype=np.float64)
    g_all = [
        np.random.default_rng(i).normal(size=p0.shape).astype(np.float64)
        for i in range(4)
    ]

    # numpy reference
    p, m, v = p0.copy(), np.zeros_like(p0), np.zeros_like(p0)
    for t, g in enumerate(g_all, start=1):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh, vh = m / (1 - b1**t), v / (1 - b2**t)
        p = p - lr * (mh / (np.sqrt(vh) + eps) + wd * p)

    opt = adamw32(lr, b1=b1, b2=b2, eps=eps, weight_decay=wd)
    params = {"w": jnp.asarray(p0, jnp.float32)}
    state = opt.init(params)
    for g in g_all:
        params, state = opt.update({"w": jnp.asarray(g, jnp.float32)}, state, params)
    np.testing.assert_allclose(np.asarray(params["w"]), p, rtol=2e-5, atol=2e-6)


# ---------------------------------------------------------------------------
# state representation & memory accounting (Tab. 4/5 claims)
# ---------------------------------------------------------------------------


def test_4bit_states_are_quantized_and_small():
    params = _params((64, 1024))  # 65536 elements > threshold
    opt4 = adamw4bit(1e-3)
    opt32 = adamw32(1e-3)
    s4, s32 = opt4.init(params), opt32.init(params)
    assert isinstance(s4["m"]["w"], QuantizedTensor)
    assert isinstance(s4["v"]["w"], QuantizedTensor)
    b4, b32 = state_nbytes(s4), state_nbytes(s32)
    # ~8x smaller modulo scale overhead (m: 0.5B + B128 scales; v: 0.5B + rank1)
    assert b4 < b32 / 6.5
    # 8-bit in between
    b8 = state_nbytes(adamw8bit(1e-3, exclude_embeddings=False).init(params))
    assert b4 < b8 < b32


def test_threshold_rule_keeps_small_tensors_fp32():
    params = {"bias": jnp.zeros((4096,)), "big": jnp.zeros((4097,))}
    s = adamw4bit(1e-3).init(params)
    assert not isinstance(s["m"]["bias"], QuantizedTensor)  # <= 4096 stays fp32
    assert isinstance(s["m"]["big"], QuantizedTensor)


def test_8bit_embedding_exclusion():
    params = {"embed_tokens": jnp.zeros((100, 128)), "dense": jnp.zeros((100, 128))}
    s = adamw8bit(1e-3).init(params)
    assert not isinstance(s["m"]["embed_tokens"], QuantizedTensor)
    assert isinstance(s["m"]["dense"], QuantizedTensor)


def test_factor4bit_state_structure():
    params = {"w2d": jnp.zeros((64, 1024)), "w1d": jnp.zeros((8192,))}
    s = factor4bit(1e-3).init(params)
    assert isinstance(s["v"]["w2d"], FactoredMoment)  # ndim>=2 factored
    assert isinstance(s["v"]["w1d"], QuantizedTensor)  # 1-d quantized
    assert isinstance(s["m"]["w2d"], QuantizedTensor)  # m always quantized
    # factored v is sublinear: (64+1024)*4 bytes << 64*1024/2
    assert s["v"]["w2d"].nbytes() == (64 + 1024) * 4


# ---------------------------------------------------------------------------
# convergence: 4-bit optimizers track 32-bit on a quadratic
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "factory", [adamw4bit, factor4bit, adamw8bit], ids=["4bit", "factor", "8bit"]
)
def test_lowbit_matches_fp32_convergence(factory):
    params = _params((16, 512), seed=1)
    target = jnp.ones_like(params["w"]) * 0.5
    steps = 250  # 4-bit v-overestimation damps the effective step ~4x;
    # convergence is retained, just needs the step budget (paper trains long).
    _, _, base = _run_steps(adamw32(2e-2), params, target, steps)
    _, _, low = _run_steps(factory(2e-2), params, target, steps)
    assert low[-1] < 0.02 * low[0]
    assert np.isfinite(low).all()


def test_zero_point_problem_destabilizes_updates():
    """Tab. 1 / Fig. 3 reproduction: quantizing the 2nd moment with a mapping
    that CONTAINS zero (DE) collapses small v entries to 0, so the next-step
    update m̂/(√v̂+ε) explodes by ~1/ε at those coordinates. Zero-excluding
    mappings (DE-0, Linear) keep updates bounded. We measure max |Δw| over a
    few steps against the fp32 trajectory — the paper's 'Unstable(%)' column
    made mechanical."""
    rng = np.random.default_rng(3)
    shape = (32, 1024)
    params = {"w": jnp.asarray(rng.normal(size=shape).astype(np.float32))}
    # row-structured gradient magnitudes (the App. B outlier pattern)
    rowscale = 10.0 ** rng.uniform(-2, 0, size=(shape[0], 1)).astype(np.float32)
    g = jnp.asarray(rng.normal(size=shape).astype(np.float32) * rowscale)
    target = params["w"] - g  # so grad == g at step 1

    def max_delta(opt):
        p, _, _ = _run_steps(opt, params, target, 8)
        return float(jnp.max(jnp.abs(p["w"] - params["w"])))

    d32 = max_delta(adamw32(1e-3))

    def v_opt(mapping):
        v_cfg = QuantConfig(
            bits=4, normalization="blockwise", block_size=128, mapping=mapping,
            signed=False,
        )
        return quantized_adamw(
            1e-3,
            m_policy=QuantPolicy(config=None),
            v_policy=QuantPolicy(config=v_cfg),
        )

    d_de = max_delta(v_opt("de"))
    d_de0 = max_delta(v_opt("de0"))
    d_lin = max_delta(v_opt("linear"))
    # DE (zero point) explodes; DE-0 and Linear stay bounded near fp32.
    assert d_de > 50 * d32
    assert d_de0 < 3 * d32
    assert d_lin < 3 * d32


# ---------------------------------------------------------------------------
# baselines run and converge
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "opt",
    [
        adafactor(2e-2, b1=0.9),
        adafactor(2e-2, b1=0.0),
        sm3(2e-1),
        sgdm(1e-2),
    ],
    ids=["adafactor", "adafactor_b1_0", "sm3", "sgdm"],
)
def test_baselines_converge(opt):
    params = _params((16, 512), seed=2)
    target = jnp.zeros_like(params["w"])
    _, _, losses = _run_steps(opt, params, target, 80)
    assert losses[-1] < 0.1 * losses[0]
    assert np.isfinite(losses).all()


def test_sgdm4bit_converges_with_sr():
    params = _params((16, 512), seed=4)
    target = jnp.zeros_like(params["w"])
    key = jax.random.PRNGKey(0)
    _, state, losses = _run_steps(sgdm4bit(5e-3), params, target, 80, key=key)
    assert isinstance(state["trace"]["w"], QuantizedTensor)
    assert losses[-1] < 0.2 * losses[0]


# ---------------------------------------------------------------------------
# jit-compatibility: whole update under jax.jit
# ---------------------------------------------------------------------------


def test_update_jits_and_matches_eager():
    params = _params((16, 512), seed=5)
    opt = adamw4bit(1e-3)
    state = opt.init(params)
    g = {"w": jnp.ones_like(params["w"]) * 0.01}

    p_e, s_e = opt.update(g, state, params)
    p_j, s_j = jax.jit(opt.update)(g, state, params)
    np.testing.assert_allclose(np.asarray(p_e["w"]), np.asarray(p_j["w"]), rtol=1e-4, atol=1e-6)
    np.testing.assert_array_equal(
        np.asarray(s_e["m"]["w"].codes), np.asarray(s_j["m"]["w"].codes)
    )
