"""Pallas kernel validation: interpret=True vs the pure-jnp ref.py oracle.

Sweeps shapes/dtypes per the deliverable spec; codes must match bit-for-bit,
floats allclose.  The stochastic-rounding path is held to the same standard:
the in-kernel Threefry noise is counter-based, so SR codes from the kernel
must match the SR oracle bit-for-bit given the same per-slice key — plus
statistical checks (unbiasedness, bounded rounding error) and equivalence
against the unfused ``compressed()`` SR path.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mappings import mapping_table
from repro.core.optimizers import adamw4bit, make_optimizer
from repro.core.optimizers.adamw import M_4BIT, V_4BIT
from repro.core.optimizers.transform import FusedAdamWRoute
from repro.core.quantizer import QuantizedTensor, quantize
from repro.kernels import ref, sr
from repro.kernels.adamw4bit import fused_adamw4
from repro.kernels.quant4 import dequantize_blockwise_4bit, quantize_blockwise_4bit

jax.config.update("jax_platform_name", "cpu")

M_TABLE = mapping_table("de", 4, signed=True)
V_TABLE = mapping_table("linear", 4, signed=False)


def _rand(shape, seed=0, scale=1.0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(dtype) * scale)


# ---------------------------------------------------------------------------
# quantize / dequantize kernels
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(128, 512), (256, 256), (8, 1024), (128, 768)])
@pytest.mark.parametrize("scale", [1e-4, 1.0, 1e3])
def test_quant_kernel_matches_ref(shape, scale):
    x = _rand(shape, seed=shape[0] + shape[1], scale=scale)
    pk, sk = quantize_blockwise_4bit(x, M_TABLE, interpret=True)
    pr, sr = ref.quant_blockwise(x, M_TABLE)
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-6)
    # round trip through the dequant kernel
    xk = dequantize_blockwise_4bit(pk, sk, M_TABLE, interpret=True)
    xr = ref.dequant_blockwise(pr, sr, M_TABLE)
    np.testing.assert_allclose(np.asarray(xk), np.asarray(xr), rtol=1e-6)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_quant_kernel_dtypes(dtype):
    x = _rand((128, 512), seed=7).astype(dtype)
    pk, sk = quantize_blockwise_4bit(x, M_TABLE, interpret=True)
    pr, sr = ref.quant_blockwise(x.astype(jnp.float32), M_TABLE)
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))


# ---------------------------------------------------------------------------
# fused AdamW kernel
# ---------------------------------------------------------------------------


def _mk_states(shape, seed):
    """Realistic packed m/v states built through the public quantizer."""
    from repro.core.optimizers.adamw import M_4BIT, V_4BIT

    m0 = _rand(shape, seed=seed, scale=0.01)
    v0 = jnp.abs(_rand(shape, seed=seed + 1, scale=0.001)) + 1e-10
    m_q = quantize(m0, M_4BIT)
    v_q = quantize(v0, V_4BIT)
    R, C = shape
    return m_q.codes, m_q.scales[0].reshape(R, C // 128), v_q.codes, v_q.scales


@pytest.mark.parametrize(
    "shape", [(128, 512), (256, 1024), (64, 256), (128, 768)]
)
def test_fused_adamw4_matches_ref(shape):
    R, C = shape
    w = _rand(shape, seed=1)
    g = _rand(shape, seed=2, scale=0.1)
    m_packed, m_scale, v_packed, (v_r, v_c) = _mk_states(shape, seed=3)

    hp = dict(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01)
    lr = jnp.float32(1e-3)
    bc1, bc2 = jnp.float32(0.1), jnp.float32(0.001)

    # oracle
    w_r, mp_r, ms_r, vp_r, vr_r, vc_r = ref.fused_adamw4_reference(
        w, g, m_packed, m_scale, v_packed, v_r, v_c, M_TABLE, V_TABLE,
        lr, hp["b1"], hp["b2"], hp["eps"], hp["weight_decay"], bc1, bc2,
    )
    # kernel (interpret mode executes the kernel body on CPU)
    tile_r = 128 if R % 128 == 0 else 64
    w_k, mp_k, ms_k, vp_k = fused_adamw4(
        w, g, m_packed, m_scale, v_packed, v_r, v_c, vr_r, vc_r,
        M_TABLE, V_TABLE, lr, bc1, bc2, interpret=True,
        tile_r=tile_r, tile_c=min(512, C), **hp,
    )
    np.testing.assert_allclose(np.asarray(w_k), np.asarray(w_r), rtol=2e-6, atol=1e-7)
    np.testing.assert_array_equal(np.asarray(mp_k), np.asarray(mp_r))
    np.testing.assert_allclose(np.asarray(ms_k), np.asarray(ms_r), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(vp_k), np.asarray(vp_r))


def test_fused_adamw4_bf16_params():
    shape = (128, 512)
    w = _rand(shape, seed=11).astype(jnp.bfloat16)
    g = _rand(shape, seed=12, scale=0.1)
    m_packed, m_scale, v_packed, (v_r, v_c) = _mk_states(shape, seed=13)
    lr, bc1, bc2 = jnp.float32(1e-3), jnp.float32(0.1), jnp.float32(0.001)
    hp = dict(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01)
    w_r, *_ = ref.fused_adamw4_reference(
        w, g, m_packed, m_scale, v_packed, v_r, v_c, M_TABLE, V_TABLE,
        lr, hp["b1"], hp["b2"], hp["eps"], hp["weight_decay"], bc1, bc2,
    )
    vr_n = jnp.max(
        hp["b2"] * ref.dequant_rank1(v_packed, v_r, v_c, V_TABLE)
        + (1 - hp["b2"]) * g * g,
        axis=1,
    )
    vc_n = jnp.max(
        hp["b2"] * ref.dequant_rank1(v_packed, v_r, v_c, V_TABLE)
        + (1 - hp["b2"]) * g * g,
        axis=0,
    )
    w_k, *_ = fused_adamw4(
        w, g, m_packed, m_scale, v_packed, v_r, v_c, vr_n, vc_n,
        M_TABLE, V_TABLE, lr, bc1, bc2, interpret=True, **hp,
    )
    assert w_k.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(w_k, np.float32), np.asarray(w_r, np.float32), rtol=2e-2
    )


# ---------------------------------------------------------------------------
# end-to-end: optimizer with use_kernel routes through the fused path
# ---------------------------------------------------------------------------


# ---------------------------------------------------------------------------
# stochastic rounding: in-kernel threefry noise
# ---------------------------------------------------------------------------


def test_threefry_matches_jax_prng():
    """The jnp-expressed Threefry-2x32 (usable inside Pallas) must be the real
    thing: bit-identical to JAX's own implementation."""
    from jax.extend import random as jex_random

    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.integers(0, 2**32, size=(2,), dtype=np.uint32))
    c = jnp.asarray(rng.integers(0, 2**32, size=(256,), dtype=np.uint32))
    expect = jex_random.threefry_2x32(k, c)  # counts split into (c0, c1) halves
    x0, x1 = sr.threefry2x32(k[0], k[1], c[:128], c[128:])
    np.testing.assert_array_equal(
        np.asarray(expect), np.asarray(jnp.concatenate([x0, x1]))
    )


def _sr_kernel_and_ref(shape, seed_words, base_seed=3):
    R, C = shape
    w = _rand(shape, seed=base_seed)
    g = _rand(shape, seed=base_seed + 1, scale=0.1)
    m_packed, m_scale, v_packed, (v_r, v_c) = _mk_states(shape, seed=base_seed + 2)
    hp = dict(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01)
    lr, bc1, bc2 = jnp.float32(1e-3), jnp.float32(0.1), jnp.float32(0.001)
    seed = jnp.asarray(seed_words, jnp.uint32)

    out_ref = ref.fused_adamw4_sr_reference(
        w, g, m_packed, m_scale, v_packed, v_r, v_c, M_TABLE, V_TABLE,
        lr, hp["b1"], hp["b2"], hp["eps"], hp["weight_decay"], bc1, bc2, seed,
    )
    w_r, mp_r, ms_r, vp_r, vr_r, vc_r = out_ref
    out_k = fused_adamw4(
        w, g, m_packed, m_scale, v_packed, v_r, v_c, vr_r, vc_r,
        M_TABLE, V_TABLE, lr, bc1, bc2, seed,
        interpret=True, use_sr=True, tile_r=pick_r(R), tile_c=min(512, C), **hp,
    )
    return out_ref, out_k


def pick_r(R):
    return 128 if R % 128 == 0 else 64


@pytest.mark.parametrize("shape", [(128, 512), (64, 256), (128, 768)])
def test_fused_adamw4_sr_kernel_matches_sr_reference(shape):
    """Counter-based noise => the SR kernel is bit-reproducible by the oracle:
    packed codes identical, floats allclose — not just statistically close."""
    (w_r, mp_r, ms_r, vp_r, _, _), (w_k, mp_k, ms_k, vp_k) = _sr_kernel_and_ref(
        shape, [123, 456]
    )
    np.testing.assert_allclose(np.asarray(w_k), np.asarray(w_r), rtol=2e-6, atol=1e-7)
    np.testing.assert_array_equal(np.asarray(mp_k), np.asarray(mp_r))
    np.testing.assert_allclose(np.asarray(ms_k), np.asarray(ms_r), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(vp_k), np.asarray(vp_r))


@pytest.mark.parametrize("use_sr", [False, True], ids=["rtn", "sr"])
def test_fused_adamw4_3d_grid_matches_per_slice_launches(use_sr):
    """Kernel-level single-launch contract: one (L, R, C) call with (L, R)
    row stats and (L, 2) seed rows is bit-identical to L separate 2-d
    launches — the outer grid dim only selects the slice's stats/seed row,
    and the SR counter stays slice-local."""
    L, R, C = 3, 64, 512
    w = _rand((L, R, C), seed=81)
    g = _rand((L, R, C), seed=82, scale=0.1)
    m0 = _rand((L, R, C), seed=83, scale=0.01)
    v0 = jnp.abs(_rand((L, R, C), seed=84, scale=0.001)) + 1e-10
    m_q, v_q = quantize(m0, M_4BIT), quantize(v0, V_4BIT)
    m_packed = m_q.codes.reshape(L, R, C // 2)
    m_scale = m_q.scales[0].reshape(L, R, C // 128)
    v_packed = v_q.codes.reshape(L, R, C // 2)
    from repro.kernels.ops import _rank1_slice_stats
    v_r, v_c = _rank1_slice_stats(v_q.scales, (L, R, C))  # (L, R), (C,)

    hp = dict(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01)
    lr, bc1, bc2 = jnp.float32(1e-3), jnp.float32(0.1), jnp.float32(0.001)
    v_old = jnp.stack(
        [ref.dequant_rank1(v_packed[l], v_r[l], v_c, V_TABLE) for l in range(L)]
    )
    v_new = hp["b2"] * v_old + (1 - hp["b2"]) * g * g
    v_r_new = jnp.max(v_new, axis=2)                      # (L, R)
    v_c_new = jnp.max(v_new, axis=(0, 1))                 # (C,)
    seeds = jnp.asarray([[3 * l + 1, 5 * l + 2] for l in range(L)], jnp.uint32)

    fused = fused_adamw4(
        w, g, m_packed, m_scale, v_packed, v_r, v_c, v_r_new, v_c_new,
        M_TABLE, V_TABLE, lr, bc1, bc2, seeds if use_sr else None,
        interpret=True, use_sr=use_sr, **hp,
    )
    for l in range(L):
        per_slice = fused_adamw4(
            w[l], g[l], m_packed[l], m_scale[l], v_packed[l],
            v_r[l], v_c, v_r_new[l], v_c_new,
            M_TABLE, V_TABLE, lr, bc1, bc2, seeds[l] if use_sr else None,
            interpret=True, use_sr=use_sr, **hp,
        )
        for a, b in zip(fused, per_slice):
            np.testing.assert_array_equal(np.asarray(a[l]), np.asarray(b))


def test_sr_kernel_tiling_invariant():
    """The noise is keyed on global element indices, so retiling the kernel
    must not change a single code (results independent of tile shape)."""
    shape = (128, 512)
    w = _rand(shape, seed=31)
    g = _rand(shape, seed=32, scale=0.1)
    m_packed, m_scale, v_packed, (v_r, v_c) = _mk_states(shape, seed=33)
    hp = dict(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01)
    lr, bc1, bc2 = jnp.float32(1e-3), jnp.float32(0.1), jnp.float32(0.001)
    v_old = ref.dequant_rank1(v_packed, v_r, v_c, V_TABLE)
    v_new = hp["b2"] * v_old + (1 - hp["b2"]) * g * g
    vr_n, vc_n = jnp.max(v_new, axis=1), jnp.max(v_new, axis=0)
    seed = jnp.asarray([7, 9], jnp.uint32)
    outs = [
        fused_adamw4(
            w, g, m_packed, m_scale, v_packed, v_r, v_c, vr_n, vc_n,
            M_TABLE, V_TABLE, lr, bc1, bc2, seed,
            interpret=True, use_sr=True, tile_r=tr, tile_c=tc, **hp,
        )
        for tr, tc in [(128, 512), (64, 256), (32, 512)]
    ]
    for other in outs[1:]:
        for a, b in zip(outs[0], other):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sr_kernel_unbiased_with_bounded_error():
    """Statistics of the in-kernel SR requantization of m: averaging the
    dequantized first moment over many keys converges to the exact update
    (unbiasedness), and every single draw stays within its bracketing table
    interval (bounded rounding error — the 'variance bound' of SR noise)."""
    shape = (8, 256)
    n_keys = 64
    g = _rand(shape, seed=41, scale=0.1)
    m_packed, m_scale, v_packed, (v_r, v_c) = _mk_states(shape, seed=42)
    hp = dict(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01)
    lr, bc1, bc2 = jnp.float32(1e-3), jnp.float32(0.1), jnp.float32(0.001)
    w = _rand(shape, seed=40)

    # exact (rounding-free) updated first moment
    m_exact = hp["b1"] * ref.dequant_blockwise(m_packed, m_scale, M_TABLE) + (
        1 - hp["b1"]
    ) * np.asarray(g)
    v_old = ref.dequant_rank1(v_packed, v_r, v_c, V_TABLE)
    v_new = hp["b2"] * v_old + (1 - hp["b2"]) * g * g
    vr_n, vc_n = jnp.max(v_new, axis=1), jnp.max(v_new, axis=0)

    deq = []
    table_np = np.asarray(M_TABLE)
    for i in range(n_keys):
        k0, k1 = sr.key_words(jax.random.PRNGKey(i))
        _, mp, ms, _ = fused_adamw4(
            w, g, m_packed, m_scale, v_packed, v_r, v_c, vr_n, vc_n,
            M_TABLE, V_TABLE, lr, bc1, bc2, jnp.stack([k0, k1]),
            interpret=True, use_sr=True, **hp,
        )
        deq.append(np.asarray(ref.dequant_blockwise(mp, ms, M_TABLE)))
        # bounded error: each draw is one of the two bracketing points, so the
        # normalized distance to the exact value never exceeds the bracket
        scale_pe = np.repeat(np.asarray(ms), 128, axis=1)
        n_exact = np.clip(m_exact / scale_pe, table_np[0], table_np[-1])
        n_drawn = deq[-1] / scale_pe
        spans = np.diff(table_np).max()
        assert np.max(np.abs(n_drawn - n_exact)) <= spans + 1e-6

    single_dev = float(np.mean([np.abs(d - m_exact).mean() for d in deq]))
    mean_bias = float(np.abs(np.mean(deq, axis=0) - m_exact).mean())
    assert single_dev > 0
    # unbiased => the 64-key average shrinks the deviation ~8x; 0.3 is slack
    assert mean_bias < 0.3 * single_dev, (mean_bias, single_dev)


def test_optimizer_kernel_sr_statistically_equivalent_to_unfused(monkeypatch):
    """The fused SR route and the unfused compressed() SR path draw different
    PRNG streams but must agree in distribution: averaged over many base
    keys, the 2-step parameter trajectories coincide far more tightly than
    any single run scatters."""
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "ref")
    params = {"w": _rand((32, 512), seed=50, scale=0.1)}
    g = {"w": _rand((32, 512), seed=51, scale=0.01)}

    def two_step_mean(use_kernel, n_keys=24):
        opt = adamw4bit(1e-3, stochastic_rounding=True, use_kernel=use_kernel)
        outs = []
        for i in range(n_keys):
            p, s = params, opt.init(params)
            for t in range(2):
                k = jax.random.fold_in(jax.random.PRNGKey(i), t)
                p, s = opt.update(g, s, p, key=k)
            outs.append(np.asarray(p["w"]))
        return np.mean(outs, axis=0), float(
            np.mean([np.abs(o - outs[0]).mean() for o in outs[1:]])
        )

    mean_fused, scatter = two_step_mean(True)
    mean_unfused, _ = two_step_mean(False)
    assert scatter > 0, "fused SR route produced no noise — key not plumbed?"
    gap = float(np.abs(mean_fused - mean_unfused).mean())
    assert gap < 0.5 * scatter, (gap, scatter)


# ---------------------------------------------------------------------------
# routing/eligibility
# ---------------------------------------------------------------------------


def _route(**kw):
    return FusedAdamWRoute(lr=1e-3, **kw)


def test_fused_route_eligibility_accepts_sr_and_stacked():
    m_sr = dataclasses.replace(M_4BIT, stochastic_rounding=True)
    v_sr = dataclasses.replace(V_4BIT, stochastic_rounding=True)
    p2 = jnp.zeros((16, 512))
    p3 = jnp.zeros((4, 16, 512))
    comp_rtn = {"m": quantize(p2, M_4BIT), "v": quantize(p2, V_4BIT)}
    comp_sr = {"m": quantize(p2, m_sr), "v": quantize(p2, v_sr)}
    comp_sr3 = {"m": quantize(p3, m_sr), "v": quantize(p3, v_sr)}
    route = _route()
    assert route.eligible(comp_rtn, p2)
    assert route.eligible(comp_sr, p2)           # SR now on the fast path
    assert route.eligible(comp_sr3, p3)          # stacked leading dims too
    # mixed SR flags would need two key streams per leaf — rejected
    mixed = {"m": quantize(p2, m_sr), "v": quantize(p2, V_4BIT)}
    assert not route.eligible(mixed, p2)
    # layout misfits stay off the kernel
    assert not route.eligible(comp_sr, jnp.zeros((16, 320)))  # 320 % 256 != 0
    assert not route.eligible({"m": comp_sr["m"]}, p2)        # missing v


def test_production4bit_body_leaves_route_through_kernel(monkeypatch):
    """Acceptance check: make_optimizer('production4bit') must put its 4-bit
    body leaves on the fused kernel route — SR enabled — while fp32 leaves
    and layout misfits take the unfused path."""
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "ref")
    from repro.kernels import ops as kernel_ops

    params = {
        "embed": _rand((64, 256), seed=60, scale=0.1),   # fp32 partition
        "body": _rand((2, 16, 512), seed=61, scale=0.1), # 4-bit, eligible
        "odd": _rand((16, 320), seed=62, scale=0.1),     # 4-bit, 320 % 256 != 0
        "bias": _rand((64,), seed=63),                   # fp32 partition
    }
    opt = make_optimizer("production4bit", 1e-3)
    state = opt.init(params)

    # the body moments are SR-configured QuantizedTensors and route-eligible
    body_state = state.states["4bit"]
    m_body = body_state["m"]["body"]
    v_body = body_state["v"]["body"]
    assert isinstance(m_body, QuantizedTensor) and m_body.config.stochastic_rounding
    route = _route()
    assert route.eligible({"m": m_body, "v": v_body}, params["body"])
    assert not route.eligible(
        {"m": body_state["m"]["odd"], "v": body_state["v"]["odd"]}, params["odd"]
    )

    seen = []
    orig = kernel_ops.fused_adamw4_leaf
    monkeypatch.setattr(
        kernel_ops,
        "fused_adamw4_leaf",
        lambda p, *a, **kw: seen.append(p.shape) or orig(p, *a, **kw),
    )
    g = {k: _rand(v.shape, seed=70, scale=0.01) for k, v in params.items()}
    p2, _ = opt.update(g, state, params, key=jax.random.PRNGKey(0))
    assert seen == [(2, 16, 512)], seen  # exactly the eligible body leaf
    assert all(np.isfinite(np.asarray(x)).all() for x in jax.tree_util.tree_leaves(p2))


def test_optimizer_kernel_path_matches_reference_path(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "interpret")
    params = {"w": _rand((64, 512), seed=20, scale=0.1)}
    g = {"w": _rand((64, 512), seed=21, scale=0.01)}

    opt_ref = adamw4bit(1e-3, use_kernel=False)
    opt_ker = adamw4bit(1e-3, use_kernel=True)
    s_ref, s_ker = opt_ref.init(params), opt_ker.init(params)
    p_ref, p_ker = params, params
    for _ in range(3):
        p_ref, s_ref = opt_ref.update(g, s_ref, p_ref)
        p_ker, s_ker = opt_ker.update(g, s_ker, p_ker)

    np.testing.assert_allclose(
        np.asarray(p_ref["w"]), np.asarray(p_ker["w"]), rtol=3e-5, atol=1e-7
    )
    np.testing.assert_array_equal(
        np.asarray(s_ref["m"]["w"].codes), np.asarray(s_ker["m"]["w"].codes)
    )
    np.testing.assert_array_equal(
        np.asarray(s_ref["v"]["w"].codes), np.asarray(s_ker["v"]["w"].codes)
    )
