"""Pallas kernel validation: interpret=True vs the pure-jnp ref.py oracle.

Sweeps shapes/dtypes per the deliverable spec; codes must match bit-for-bit,
floats allclose.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mappings import mapping_table
from repro.core.optimizers import adamw4bit
from repro.core.quantizer import QuantizedTensor, quantize
from repro.kernels import ref
from repro.kernels.adamw4bit import fused_adamw4
from repro.kernels.quant4 import dequantize_blockwise_4bit, quantize_blockwise_4bit

jax.config.update("jax_platform_name", "cpu")

M_TABLE = mapping_table("de", 4, signed=True)
V_TABLE = mapping_table("linear", 4, signed=False)


def _rand(shape, seed=0, scale=1.0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(dtype) * scale)


# ---------------------------------------------------------------------------
# quantize / dequantize kernels
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(128, 512), (256, 256), (8, 1024), (128, 768)])
@pytest.mark.parametrize("scale", [1e-4, 1.0, 1e3])
def test_quant_kernel_matches_ref(shape, scale):
    x = _rand(shape, seed=shape[0] + shape[1], scale=scale)
    pk, sk = quantize_blockwise_4bit(x, M_TABLE, interpret=True)
    pr, sr = ref.quant_blockwise(x, M_TABLE)
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-6)
    # round trip through the dequant kernel
    xk = dequantize_blockwise_4bit(pk, sk, M_TABLE, interpret=True)
    xr = ref.dequant_blockwise(pr, sr, M_TABLE)
    np.testing.assert_allclose(np.asarray(xk), np.asarray(xr), rtol=1e-6)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_quant_kernel_dtypes(dtype):
    x = _rand((128, 512), seed=7).astype(dtype)
    pk, sk = quantize_blockwise_4bit(x, M_TABLE, interpret=True)
    pr, sr = ref.quant_blockwise(x.astype(jnp.float32), M_TABLE)
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))


# ---------------------------------------------------------------------------
# fused AdamW kernel
# ---------------------------------------------------------------------------


def _mk_states(shape, seed):
    """Realistic packed m/v states built through the public quantizer."""
    from repro.core.optimizers.adamw import M_4BIT, V_4BIT

    m0 = _rand(shape, seed=seed, scale=0.01)
    v0 = jnp.abs(_rand(shape, seed=seed + 1, scale=0.001)) + 1e-10
    m_q = quantize(m0, M_4BIT)
    v_q = quantize(v0, V_4BIT)
    R, C = shape
    return m_q.codes, m_q.scales[0].reshape(R, C // 128), v_q.codes, v_q.scales


@pytest.mark.parametrize(
    "shape", [(128, 512), (256, 1024), (64, 256), (128, 768)]
)
def test_fused_adamw4_matches_ref(shape):
    R, C = shape
    w = _rand(shape, seed=1)
    g = _rand(shape, seed=2, scale=0.1)
    m_packed, m_scale, v_packed, (v_r, v_c) = _mk_states(shape, seed=3)

    hp = dict(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01)
    lr = jnp.float32(1e-3)
    bc1, bc2 = jnp.float32(0.1), jnp.float32(0.001)

    # oracle
    w_r, mp_r, ms_r, vp_r, vr_r, vc_r = ref.fused_adamw4_reference(
        w, g, m_packed, m_scale, v_packed, v_r, v_c, M_TABLE, V_TABLE,
        lr, hp["b1"], hp["b2"], hp["eps"], hp["weight_decay"], bc1, bc2,
    )
    # kernel (interpret mode executes the kernel body on CPU)
    tile_r = 128 if R % 128 == 0 else 64
    w_k, mp_k, ms_k, vp_k = fused_adamw4(
        w, g, m_packed, m_scale, v_packed, v_r, v_c, vr_r, vc_r,
        M_TABLE, V_TABLE, lr, bc1, bc2, interpret=True,
        tile_r=tile_r, tile_c=min(512, C), **hp,
    )
    np.testing.assert_allclose(np.asarray(w_k), np.asarray(w_r), rtol=2e-6, atol=1e-7)
    np.testing.assert_array_equal(np.asarray(mp_k), np.asarray(mp_r))
    np.testing.assert_allclose(np.asarray(ms_k), np.asarray(ms_r), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(vp_k), np.asarray(vp_r))


def test_fused_adamw4_bf16_params():
    shape = (128, 512)
    w = _rand(shape, seed=11).astype(jnp.bfloat16)
    g = _rand(shape, seed=12, scale=0.1)
    m_packed, m_scale, v_packed, (v_r, v_c) = _mk_states(shape, seed=13)
    lr, bc1, bc2 = jnp.float32(1e-3), jnp.float32(0.1), jnp.float32(0.001)
    hp = dict(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01)
    w_r, *_ = ref.fused_adamw4_reference(
        w, g, m_packed, m_scale, v_packed, v_r, v_c, M_TABLE, V_TABLE,
        lr, hp["b1"], hp["b2"], hp["eps"], hp["weight_decay"], bc1, bc2,
    )
    vr_n = jnp.max(
        hp["b2"] * ref.dequant_rank1(v_packed, v_r, v_c, V_TABLE)
        + (1 - hp["b2"]) * g * g,
        axis=1,
    )
    vc_n = jnp.max(
        hp["b2"] * ref.dequant_rank1(v_packed, v_r, v_c, V_TABLE)
        + (1 - hp["b2"]) * g * g,
        axis=0,
    )
    w_k, *_ = fused_adamw4(
        w, g, m_packed, m_scale, v_packed, v_r, v_c, vr_n, vc_n,
        M_TABLE, V_TABLE, lr, bc1, bc2, interpret=True, **hp,
    )
    assert w_k.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(w_k, np.float32), np.asarray(w_r, np.float32), rtol=2e-2
    )


# ---------------------------------------------------------------------------
# end-to-end: optimizer with use_kernel routes through the fused path
# ---------------------------------------------------------------------------


def test_optimizer_kernel_path_matches_reference_path(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "interpret")
    params = {"w": _rand((64, 512), seed=20, scale=0.1)}
    g = {"w": _rand((64, 512), seed=21, scale=0.01)}

    opt_ref = adamw4bit(1e-3, use_kernel=False)
    opt_ker = adamw4bit(1e-3, use_kernel=True)
    s_ref, s_ker = opt_ref.init(params), opt_ker.init(params)
    p_ref, p_ker = params, params
    for _ in range(3):
        p_ref, s_ref = opt_ref.update(g, s_ref, p_ref)
        p_ker, s_ker = opt_ker.update(g, s_ker, p_ker)

    np.testing.assert_allclose(
        np.asarray(p_ref["w"]), np.asarray(p_ker["w"]), rtol=3e-5, atol=1e-7
    )
    np.testing.assert_array_equal(
        np.asarray(s_ref["m"]["w"].codes), np.asarray(s_ker["m"]["w"].codes)
    )
    np.testing.assert_array_equal(
        np.asarray(s_ref["v"]["w"].codes), np.asarray(s_ker["v"]["w"].codes)
    )
