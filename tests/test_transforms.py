"""Transform-API tests: bit-exact equivalence of every chain-built
constructor vs the pre-refactor monolithic loops (tests/legacy_optimizers.py),
partition() routing, and the structured make_optimizer factory."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from legacy_optimizers import (
    M_8BIT as LEGACY_M_8BIT,
    V_8BIT as LEGACY_V_8BIT,
    legacy_adafactor,
    legacy_quantized_adamw,
    legacy_sgdm,
    legacy_sgdm4bit,
    legacy_sm3,
)
from repro.core.optimizers import (
    QuantPolicy,
    adafactor,
    adamw4bit,
    adamw8bit,
    adamw32,
    add_decayed_weights,
    as_optimizer,
    chain,
    compressed,
    factor4bit,
    label_by_regex,
    linear_warmup_linear_decay,
    make_optimizer,
    optimizer_names,
    partition,
    scale_by_adam,
    scale_by_learning_rate,
    sgdm,
    sgdm4bit,
    sm3,
    state_nbytes,
)
from repro.core.optimizers.adamw import M_4BIT, V_4BIT
from repro.core.optimizers.transform import ChainState
from repro.core.quantizer import QuantizedTensor

jax.config.update("jax_platform_name", "cpu")


def _mixed_params():
    """Exercises every Alg. 1 leaf mode at once: quantized 2-d (kernel-shaped
    and odd-shaped), quantized 1-d, raw small bias, raw scalar."""
    rng = np.random.default_rng(0)
    f32 = lambda a: jnp.asarray(a.astype(np.float32))
    return {
        "embed_tokens": f32(rng.normal(size=(64, 256)) * 0.1),  # 8-bit exclusion hits this
        "w2d": f32(rng.normal(size=(16, 512)) * 0.1),  # kernel-eligible shape
        "odd": f32(rng.normal(size=(16, 300)) * 0.1),  # quantized, kernel-ineligible
        "w1d": f32(rng.normal(size=(8192,)) * 0.1),  # rank-1 1-d path
        "bias": f32(rng.normal(size=(64,)) * 0.1),  # below threshold -> raw
        "scalar": jnp.float32(0.3),
    }


def _grads_at(t, params):
    rng = np.random.default_rng(1000 + t)
    return jax.tree_util.tree_map(
        lambda p: jnp.asarray(rng.normal(size=p.shape).astype(np.float32) * 0.02),
        params,
    )


def _assert_trees_bitwise(a, b, what):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb), f"{what}: leaf count {len(la)} != {len(lb)}"
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y), err_msg=what)


def _run_pair(opt_new, opt_old, steps=5, with_key=False, state_fields=("m", "v")):
    params = _mixed_params()
    p_new, p_old = params, params
    s_new, s_old = opt_new.init(params), opt_old.init(params)
    upd_new, upd_old = jax.jit(opt_new.update), jax.jit(opt_old.update)
    base = jax.random.PRNGKey(7)
    for t in range(steps):
        g = _grads_at(t, params)
        k = jax.random.fold_in(base, t) if with_key else None
        if k is not None:
            p_new, s_new = upd_new(g, s_new, p_new, key=k)
            p_old, s_old = upd_old(g, s_old, p_old, key=k)
        else:
            p_new, s_new = upd_new(g, s_new, p_new)
            p_old, s_old = upd_old(g, s_old, p_old)
        _assert_trees_bitwise(p_new, p_old, f"params @ step {t}")
    for field in state_fields:
        _assert_trees_bitwise(s_new[field], s_old[field], f"state[{field!r}]")
    return p_new, s_new, p_old, s_old


# ---------------------------------------------------------------------------
# bit-exact equivalence: chain rebuilds vs the pre-refactor loops
# ---------------------------------------------------------------------------

LR_SCHED = linear_warmup_linear_decay(3e-3, 2, 50)


@pytest.mark.parametrize(
    "new_factory, old_kwargs",
    [
        (adamw32, {}),
        (
            adamw8bit,
            dict(
                m_policy=QuantPolicy(config=LEGACY_M_8BIT, exclude=("embed",)),
                v_policy=QuantPolicy(config=LEGACY_V_8BIT, exclude=("embed",)),
            ),
        ),
        (
            adamw4bit,
            dict(m_policy=QuantPolicy(config=M_4BIT), v_policy=QuantPolicy(config=V_4BIT)),
        ),
        (
            factor4bit,
            dict(
                m_policy=QuantPolicy(config=M_4BIT),
                v_policy=QuantPolicy(config=V_4BIT, factor_2d=True),
            ),
        ),
    ],
    ids=["adamw32", "adamw8bit", "adamw4bit", "factor4bit"],
)
def test_adamw_family_bit_identical(new_factory, old_kwargs):
    _run_pair(new_factory(LR_SCHED), legacy_quantized_adamw(LR_SCHED, **old_kwargs))


def test_adamw4bit_stochastic_rounding_bit_identical():
    import dataclasses

    m_cfg = dataclasses.replace(M_4BIT, stochastic_rounding=True)
    v_cfg = dataclasses.replace(V_4BIT, stochastic_rounding=True)
    _run_pair(
        adamw4bit(1e-3, stochastic_rounding=True),
        legacy_quantized_adamw(
            1e-3,
            m_policy=QuantPolicy(config=m_cfg),
            v_policy=QuantPolicy(config=v_cfg),
        ),
        with_key=True,
    )


def test_adamw4bit_kernel_path_bit_identical(monkeypatch):
    """use_kernel=True engages the same fused route in old and new; the mixed
    tree has both eligible (w2d, embed_tokens) and ineligible leaves."""
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "ref")
    p_new, s_new, _, _ = _run_pair(
        adamw4bit(1e-3, use_kernel=True),
        legacy_quantized_adamw(
            1e-3,
            m_policy=QuantPolicy(config=M_4BIT),
            v_policy=QuantPolicy(config=V_4BIT),
            use_kernel=True,
        ),
    )
    # sanity: the eligible leaf really is on the quantized path
    assert isinstance(s_new["m"]["w2d"], QuantizedTensor)


def test_sgdm_bit_identical():
    _run_pair(
        sgdm(LR_SCHED, weight_decay=0.01),
        legacy_sgdm(LR_SCHED, weight_decay=0.01),
        state_fields=(),
    )


def test_sgdm4bit_sr_bit_identical():
    # momentum field renamed m -> trace; compare against the legacy "m" tree
    _, s_new, _, s_old = _run_pair(
        sgdm4bit(5e-3), legacy_sgdm4bit(5e-3), with_key=True, state_fields=()
    )
    _assert_trees_bitwise(s_new["trace"], s_old["m"], "sgdm trace vs legacy m")


def test_sm3_bit_identical():
    _run_pair(sm3(2e-1), legacy_sm3(2e-1), state_fields=("m", "acc"))


@pytest.mark.parametrize("b1", [0.9, 0.0], ids=["b1_09", "b1_0"])
def test_adafactor_bit_identical(b1):
    fields = ("v", "m") if b1 > 0 else ("v",)
    _run_pair(
        adafactor(LR_SCHED, b1=b1), legacy_adafactor(LR_SCHED, b1=b1), state_fields=fields
    )


# ---------------------------------------------------------------------------
# partition(): per-subtree optimizer choice
# ---------------------------------------------------------------------------


def _adamw_chain(m_policy=None, v_policy=None):
    return chain(
        compressed(
            scale_by_adam(), {"m": m_policy or QuantPolicy(), "v": v_policy or QuantPolicy()}
        ),
        add_decayed_weights(0.01),
        scale_by_learning_rate(1e-3),
    )


def test_partition_routes_embeddings_fp32():
    labels = label_by_regex(("embed",), "fp32", "4bit")
    tx = partition(
        {
            "fp32": _adamw_chain(),
            "4bit": _adamw_chain(QuantPolicy(config=M_4BIT), QuantPolicy(config=V_4BIT)),
        },
        labels,
    )
    opt = as_optimizer(tx, name="partitioned")
    params = _mixed_params()
    state = opt.init(params)
    m_fp32 = state.states["fp32"]["m"]
    m_4bit = state.states["4bit"]["m"]
    # embeddings live (raw fp32) in the fp32 partition, body is quantized
    assert not isinstance(m_fp32["embed_tokens"], QuantizedTensor)
    assert hasattr(m_fp32["embed_tokens"], "shape")
    assert isinstance(m_4bit["w2d"], QuantizedTensor)
    # the 4-bit partition holds no state for the embedding leaf
    assert m_4bit["embed_tokens"] == ()  # MaskedNode flattens to nothing

    # two steps run without structure errors and move every leaf
    p = params
    for t in range(2):
        p, state = opt.update(_grads_at(t, params), state, p)
    for k in params:
        assert not np.array_equal(np.asarray(p[k]), np.asarray(params[k]))


def test_partition_matches_per_subtree_runs():
    """partition(full tree) == running each optimizer on its own subtree
    (transforms are leaf-local, so routing must not change trajectories)."""
    labels = label_by_regex(("embed",), "a", "b")
    tx = partition(
        {"a": _adamw_chain(), "b": _adamw_chain(QuantPolicy(config=M_4BIT), QuantPolicy(config=V_4BIT))},
        labels,
    )
    opt = as_optimizer(tx)
    params = _mixed_params()
    p, s = params, opt.init(params)
    for t in range(3):
        p, s = opt.update(_grads_at(t, params), s, p)

    # reference: each sub-optimizer on its own restricted tree
    sub_a = {k: v for k, v in params.items() if "embed" in k}
    sub_b = {k: v for k, v in params.items() if "embed" not in k}
    opt_a = as_optimizer(_adamw_chain())
    opt_b = as_optimizer(_adamw_chain(QuantPolicy(config=M_4BIT), QuantPolicy(config=V_4BIT)))
    pa, sa = sub_a, opt_a.init(sub_a)
    pb, sb = sub_b, opt_b.init(sub_b)
    for t in range(3):
        g = _grads_at(t, params)
        pa, sa = opt_a.update({k: g[k] for k in sub_a}, sa, pa)
        pb, sb = opt_b.update({k: g[k] for k in sub_b}, sb, pb)
    for k in sub_a:
        np.testing.assert_array_equal(np.asarray(p[k]), np.asarray(pa[k]))
    for k in sub_b:
        np.testing.assert_array_equal(np.asarray(p[k]), np.asarray(pb[k]))


def test_partition_unknown_label_raises():
    tx = partition({"a": _adamw_chain()}, lambda path, p: "b")
    with pytest.raises(ValueError, match="no transform"):
        tx.init(_mixed_params())


def test_partition_jits():
    labels = label_by_regex(("embed",), "fp32", "4bit")
    tx = partition(
        {"fp32": _adamw_chain(), "4bit": _adamw_chain(QuantPolicy(config=M_4BIT), QuantPolicy(config=V_4BIT))},
        labels,
    )
    opt = as_optimizer(tx)
    params = _mixed_params()
    s = opt.init(params)
    g = _grads_at(0, params)
    p_e, _ = opt.update(g, s, params)
    p_j, _ = jax.jit(opt.update)(g, s, params)
    for k in params:
        np.testing.assert_allclose(
            np.asarray(p_e[k]), np.asarray(p_j[k]), rtol=1e-6, atol=1e-7
        )


# ---------------------------------------------------------------------------
# structured factory
# ---------------------------------------------------------------------------


def test_make_optimizer_builds_every_registered_name():
    params = _mixed_params()
    for name in optimizer_names():
        opt = make_optimizer(name, 1e-3)
        s = opt.init(params)
        g = _grads_at(0, params)
        if name == "sgdm4bit":
            p2, _ = opt.update(g, s, params, key=jax.random.PRNGKey(0))
        else:
            p2, _ = opt.update(g, s, params)
        assert all(
            np.isfinite(np.asarray(l)).all() for l in jax.tree_util.tree_leaves(p2)
        )


def test_make_optimizer_validates_name_and_overrides():
    with pytest.raises(ValueError, match="unknown optimizer"):
        make_optimizer("adamw2bit", 1e-3)
    with pytest.raises(ValueError, match="does not accept"):
        make_optimizer("sm3", 1e-3, stochastic_rounding=True)
    with pytest.raises(ValueError, match="does not accept"):
        make_optimizer("adamw4bit", 1e-3, use_kernle=True)  # typo caught
    # valid overrides pass through, including **kw-forwarded ones
    opt = make_optimizer("adamw4bit", 1e-3, use_kernel=True, weight_decay=0.1)
    assert opt.name == "adamw4bit"
    # **kw validation follows each factory's REAL forwarding target:
    # sgdm4bit forwards to sgdm, which has no eps
    with pytest.raises(ValueError, match="does not accept"):
        make_optimizer("sgdm4bit", 1e-3, eps=1e-6)
    assert make_optimizer("sgdm4bit", 1e-3, weight_decay=0.1).name == "sgdm4bit"
    # params the wrapper hard-binds fail loudly too, not with a raw TypeError
    with pytest.raises(ValueError, match="rejected overrides"):
        make_optimizer("adamw4bit", 1e-3, m_policy=QuantPolicy())


# ---------------------------------------------------------------------------
# chain-state ergonomics
# ---------------------------------------------------------------------------


def test_chain_state_field_lookup_and_nbytes():
    params = _mixed_params()
    opt = adamw4bit(1e-3)
    s = opt.init(params)
    assert isinstance(s, ChainState)
    assert isinstance(s["m"]["w2d"], QuantizedTensor)  # migration-compat view
    assert isinstance(s[0].inner.m["w2d"], QuantizedTensor)  # positional view
    with pytest.raises(KeyError):
        s["nope"]
    # adafactor(b1=0) has no first moment: lookup must raise like the old
    # dict state did, not return the None field
    with pytest.raises(KeyError):
        adafactor(1e-3, b1=0.0).init(params)["m"]
    assert state_nbytes(s) < state_nbytes(adamw32(1e-3).init(params)) / 4


def test_chain_state_survives_eval_shape_and_checkpoint_structure():
    params = _mixed_params()
    opt = adamw4bit(1e-3)
    s = opt.init(params)
    s_shape = jax.eval_shape(lambda: opt.init(params))
    assert jax.tree_util.tree_structure(s) == jax.tree_util.tree_structure(s_shape)


@pytest.mark.parametrize(
    "factory", [adamw4bit, factor4bit, sm3, adafactor, sgdm4bit],
    ids=["adamw4bit", "factor4bit", "sm3", "adafactor", "sgdm4bit"],
)
def test_opt_state_shardings_mirror_chain_states(factory):
    """The generic sharding walker must emit one sharding per state array,
    preserving the exact chain-state tree structure (jit in_shardings needs
    this) — including layouts the old dict walker could not handle (sm3
    accumulator tuples, adafactor's optional momentum)."""
    from jax.sharding import NamedSharding

    from repro.sharding.specs import opt_state_shardings

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = _mixed_params()
    axes = jax.tree_util.tree_map(lambda p: ("embed",) * p.ndim, params)
    state = factory(1e-3).init(params)
    sh = opt_state_shardings(state, params, axes, mesh, zero=True)
    assert jax.tree_util.tree_structure(state) == jax.tree_util.tree_structure(sh)
    assert all(
        isinstance(l, NamedSharding) for l in jax.tree_util.tree_leaves(sh)
    )
