"""Quantized gradient communication (src/repro/comms).

The enforced invariants:

* ``quantized_all_reduce`` inside shard_map over 8 ranks is bitwise equal to
  the host oracle (quantize each rank's partial with the counter-based
  transport uniforms, dequantize, sum) — the wire really moves codes+scales.
* Stochastic transport rounding is unbiased: averaging the reduced value
  over independent keys converges to the true fp32 sum.
* ``reduce_grads`` is bit-identical across mesh layouts (2x4, 4x2, and the
  no-mesh numerics path) given the same logical gradients — the property
  that makes int4 transport safe under elastic restarts.  This is exactly
  where ``jax.random.uniform``-based SR fails (its draws depend on output
  sharding under the default non-partitionable Threefry), hence the
  counter-based derivation in ``repro.kernels.sr``.
* int4-comms training: save -> restore -> continue on the same mesh is
  bit-exact end to end; an elastic (2,4) -> (4,2) restore stays close and
  finite (reduction order upstream of comms legitimately differs).
* Accounting is exact: ``leaf_wire_bytes`` matches the bytes of the real
  quantized payload, and int4 clears the >= 4x acceptance floor.
"""

import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.comms import (
    CommsConfig,
    format_wire_table,
    grad_comm_key,
    leaf_wire_bytes,
    mode_totals,
    quantized_all_reduce,
    reduce_grads,
    wire_report,
)
from repro.core.optimizers import make_optimizer
from repro.core.quantizer import dequantize, quantize
from repro.kernels.sr import STREAM_GRAD, tensor_uniforms
from repro.models import LayerSpec, ModelConfig, init_model
from repro.train.checkpoint import restore_checkpoint, save_checkpoint
from repro.train.train_loop import (
    build_train_step,
    jit_train_step,
    make_train_state,
    train_state_shardings,
)

jax.config.update("jax_platform_name", "cpu")

needs_8_devices = pytest.mark.skipif(
    len(jax.devices()) < 8, reason="needs the 8-device host harness"
)


# ---------------------------------------------------------------------------
# config / migration
# ---------------------------------------------------------------------------


def test_commsconfig_parse_and_properties():
    cfg = CommsConfig.parse("INT4")
    assert cfg.mode == "int4" and cfg.bits == 4 and cfg.quantized
    assert cfg.compresses and cfg.cast_dtype is None
    q = cfg.quant_config()
    assert q.bits == 4 and q.signed and q.normalization == "blockwise"
    assert q.block_size == 128 and q.stochastic_rounding
    assert "int4" in cfg.name and "+SR" in cfg.name

    bf16 = CommsConfig(mode="bf16")
    assert not bf16.quantized and bf16.compresses
    assert bf16.cast_dtype == jnp.bfloat16 and bf16.quant_config() is None

    fp32 = CommsConfig()
    assert not fp32.compresses and fp32.quant_config() is None

    with pytest.raises(ValueError, match="unknown grad-comm mode"):
        CommsConfig(mode="int2")


def test_commsconfig_validates_mapping():
    # The mapping registry is the gatekeeper even for transport configs —
    # typos fail at construction, listing the registered maps.
    from repro.core import mappings

    with pytest.raises(ValueError, match="registered mappings"):
        CommsConfig(mode="int4", mapping="ed")
    for name in mappings.registered():
        assert CommsConfig(mode="int4", mapping=name).quant_config().mapping == name


def test_grad_dtype_knob_is_gone():
    # PR 6's deprecation path is finished: CommsConfig is the ONLY
    # wire-format knob, and the legacy kwarg fails loudly.
    cfg = _MICRO_CFG
    opt = make_optimizer("adamw32", 1e-3)
    with pytest.raises(TypeError):
        build_train_step(cfg, opt, grad_dtype=jnp.bfloat16)
    import repro.comms as comms_mod

    assert not hasattr(comms_mod, "from_grad_dtype")


# ---------------------------------------------------------------------------
# accounting
# ---------------------------------------------------------------------------


def _grads_fixture():
    rng = np.random.default_rng(0)
    return {
        "embed": jnp.asarray(rng.standard_normal((256, 64), dtype=np.float32)),
        "w": jnp.asarray(rng.standard_normal((128, 128), dtype=np.float32)),
        "bias": jnp.asarray(rng.standard_normal((64,), dtype=np.float32)),
    }


def test_leaf_wire_bytes_matches_real_payload():
    cfg = CommsConfig(mode="int4")
    qcfg = cfg.quant_config()
    g = _grads_fixture()["embed"]
    q = quantize(g, qcfg)
    fp32, wire = leaf_wire_bytes(g.shape, cfg)
    assert fp32 == g.size * 4
    assert wire == q.nbytes()  # codes + scales, exactly what the wire moves
    # sub-threshold leaves move fp32 in every mode
    assert leaf_wire_bytes((64,), cfg) == (256, 256)
    assert leaf_wire_bytes((64,), CommsConfig(mode="bf16")) == (256, 256 // 2)


def test_wire_report_ratios_and_floor():
    grads = _grads_fixture()
    reports = {r["mode"]: r for r in mode_totals(grads)}
    assert reports["fp32"]["ratio_vs_fp32"] == 1.0
    assert reports["bf16"]["ratio_vs_fp32"] == pytest.approx(2.0)
    assert reports["int8"]["ratio_vs_fp32"] > 3.5
    assert reports["int4"]["ratio_vs_fp32"] >= 4.0  # acceptance floor
    r = wire_report(grads, CommsConfig(mode="int4"))
    assert r["quantized_leaves"] == 2 and r["n_leaves"] == 3
    assert sum(row["wire_bytes"] for row in r["leaves"]) == r["total_wire_bytes"]
    table = format_wire_table(mode_totals(grads), title="t")
    assert "int4" in table and "| grad-comm |" in table


def test_wire_report_gpt2m_acceptance_floor():
    """ISSUE acceptance: >= 4x fewer gradient-collective bytes per step for
    int4 on the production-sized (GPT-2-M) tree."""
    from benchmarks.tables import _gpt2m_like_params

    r = wire_report(_gpt2m_like_params(), CommsConfig(mode="int4"))
    assert r["ratio_vs_fp32"] >= 4.0


# ---------------------------------------------------------------------------
# reduce_grads numerics
# ---------------------------------------------------------------------------


def test_reduce_grads_fp32_and_bf16_modes():
    grads = _grads_fixture()
    out = reduce_grads(grads, None, None, CommsConfig())
    for k in grads:
        np.testing.assert_array_equal(out[k], grads[k])
    out = reduce_grads(grads, None, None, CommsConfig(mode="bf16"))
    for k in grads:
        assert out[k].dtype == jnp.bfloat16
        np.testing.assert_array_equal(out[k], grads[k].astype(jnp.bfloat16))


def test_reduce_grads_quantized_threshold_and_error():
    grads = _grads_fixture()
    cfg = CommsConfig(mode="int4")
    key = grad_comm_key(jax.random.PRNGKey(0), jnp.int32(0))
    out = reduce_grads(grads, None, None, cfg, key=key)
    # sub-threshold leaf passes through untouched (and fp32)
    np.testing.assert_array_equal(out["bias"], grads["bias"])
    # quantized leaves carry bounded blockwise-relative error
    for k in ("embed", "w"):
        g = np.asarray(grads[k])
        d = np.abs(np.asarray(out[k]) - g)
        assert d.max() <= np.abs(g).max()  # scales bound the error
        assert d.mean() < 0.2 * np.abs(g).mean()
        assert not np.array_equal(np.asarray(out[k]), g)


def test_reduce_grads_rtn_without_key_is_deterministic():
    grads = _grads_fixture()
    cfg = CommsConfig(mode="int4")
    a = reduce_grads(grads, None, None, cfg, key=None)
    b = reduce_grads(grads, None, None, cfg, key=None)
    for k in grads:
        np.testing.assert_array_equal(a[k], b[k])


def test_grad_comm_key_stream():
    assert grad_comm_key(None, jnp.int32(3)) is None
    base = jax.random.PRNGKey(7)
    k3 = grad_comm_key(base, jnp.int32(3))
    # pure function of (base, step): replayable, and step-separated
    assert np.array_equal(
        jax.random.key_data(k3),
        jax.random.key_data(grad_comm_key(base, jnp.int32(3))),
    )
    k4 = grad_comm_key(base, jnp.int32(4))
    assert not np.array_equal(jax.random.key_data(k3), jax.random.key_data(k4))
    # domain-separated from the optimizer's per-step key
    opt_k3 = jax.random.fold_in(base, jnp.int32(3))
    assert not np.array_equal(jax.random.key_data(k3), jax.random.key_data(opt_k3))


_AXES = {"embed": ("vocab", "embed"), "w": ("embed", "mlp"), "bias": ("embed",)}


def _run_reduce(grads, cfg, key, mesh_shape):
    if mesh_shape is None:
        fn = jax.jit(lambda g: reduce_grads(g, None, None, cfg, key=key))
        return jax.device_get(fn(grads))
    devs = np.array(jax.devices()[: mesh_shape[0] * mesh_shape[1]]).reshape(mesh_shape)
    mesh = Mesh(devs, ("data", "model"))
    fn = jax.jit(lambda g: reduce_grads(g, _AXES, mesh, cfg, key=key))
    with mesh:
        return jax.device_get(fn(grads))


@needs_8_devices
@pytest.mark.parametrize("mode", ["int4", "int8"])
def test_reduce_grads_bit_identical_across_mesh_layouts(mode):
    """The elastic-restart guarantee: same logical gradients + same
    checkpointed key stream -> bit-identical reduced gradients on (2,4),
    (4,2), and without a mesh.  Fails with jax.random-based SR."""
    grads = _grads_fixture()
    cfg = CommsConfig(mode=mode)
    key = grad_comm_key(jax.random.PRNGKey(7), jnp.int32(3))
    r24 = _run_reduce(grads, cfg, key, (2, 4))
    r42 = _run_reduce(grads, cfg, key, (4, 2))
    rn = _run_reduce(grads, cfg, key, None)
    for k in grads:
        np.testing.assert_array_equal(r24[k], r42[k], err_msg=f"2x4 vs 4x2: {k}")
        np.testing.assert_array_equal(r24[k], rn[k], err_msg=f"mesh vs none: {k}")


# ---------------------------------------------------------------------------
# quantized_all_reduce (the shard_map wire primitive)
# ---------------------------------------------------------------------------


def _all_reduce_fn(mesh, qcfg, key):
    @functools.partial(
        shard_map, mesh=mesh, in_specs=P("data"), out_specs=P("data"),
        check_rep=False,  # vmapped dequantize defeats replication inference
    )
    def reduced(xs):
        return quantized_all_reduce(xs[0], qcfg, "data", key=key)[None]

    return reduced


@needs_8_devices
def test_quantized_all_reduce_matches_host_oracle():
    qcfg = CommsConfig(mode="int4").quant_config()
    key = jax.random.PRNGKey(11)
    mesh = Mesh(np.array(jax.devices()[:8]).reshape(8), ("data",))
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((8, 16, 128), dtype=np.float32))

    out = jax.device_get(_all_reduce_fn(mesh, qcfg, key)(x))
    for r in range(1, 8):  # every rank holds the same reduced value
        np.testing.assert_array_equal(out[0], out[r])

    deqs = []
    for r in range(8):
        kr = jax.random.fold_in(key, r)
        u = tensor_uniforms(kr, (16, 128), STREAM_GRAD)
        deqs.append(dequantize(quantize(x[r], qcfg, uniforms=u)))
    oracle = jax.device_get(jnp.sum(jnp.stack(deqs), axis=0))
    np.testing.assert_array_equal(out[0], oracle)


@needs_8_devices
def test_quantized_all_reduce_sr_unbiased():
    """Mean over independent keys approaches the exact fp32 sum ~1/sqrt(K)
    — the transported quantization noise is zero-mean (App. E.3 transferred
    to the wire)."""
    qcfg = CommsConfig(mode="int4").quant_config()
    mesh = Mesh(np.array(jax.devices()[:8]).reshape(8), ("data",))
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((8, 8, 128), dtype=np.float32))
    true = jax.device_get(jnp.sum(x, axis=0))

    n_keys = 16
    acc = np.zeros_like(true)
    for s in range(n_keys):
        out = jax.device_get(
            _all_reduce_fn(mesh, qcfg, jax.random.PRNGKey(100 + s))(x)
        )
        acc += out[0]
    single_err = np.abs(
        jax.device_get(_all_reduce_fn(mesh, qcfg, jax.random.PRNGKey(100))(x))[0]
        - true
    ).mean()
    mean_err = np.abs(acc / n_keys - true).mean()
    assert mean_err < 0.5 * single_err, (mean_err, single_err)


# ---------------------------------------------------------------------------
# end-to-end training with int4 transport
# ---------------------------------------------------------------------------

_MICRO_CFG = ModelConfig(
    name="micro-comms-lm",
    num_layers=1,
    d_model=64,
    num_heads=2,
    num_kv_heads=1,
    head_dim=32,
    d_ff=128,
    vocab_size=256,  # embed 256*64 = 16384 > threshold -> quantized transport
    blocks=(LayerSpec("dense", 0),),
    remat=False,
)


def _batch(t):
    from repro.data.pipeline import DataConfig, SyntheticLM

    data = SyntheticLM(DataConfig(_MICRO_CFG.vocab_size, 16, 8, seed=2))
    return {k: jnp.asarray(v) for k, v in data.batch_at(t).items()}


def _assert_states_bitwise(a, b, what=""):
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb, f"{what}: tree structure mismatch"
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y), err_msg=what)


def _comms_mesh_step(opt, mesh, axes, state, comms):
    step = build_train_step(_MICRO_CFG, opt, mesh, axes, zero=True, comms=comms)
    return jit_train_step(step, state, _batch(0), axes, mesh, donate=False)


def test_int4_comms_training_moves_loss_single_process():
    """The numerics-only path: int4 transport trains the micro LM to a loss
    close to the fp32-collective run (same seeds)."""
    opt = make_optimizer("adamw32", 3e-3)
    losses = {}
    for mode in ("fp32", "int4"):
        params, _ = init_model(jax.random.PRNGKey(0), _MICRO_CFG)
        state = make_train_state(params, opt, key=jax.random.PRNGKey(5))
        step = jax.jit(
            build_train_step(_MICRO_CFG, opt, comms=CommsConfig(mode=mode))
        )
        for t in range(12):
            state, metrics = step(state, _batch(t))
        losses[mode] = float(metrics["loss"])
    assert np.isfinite(losses["int4"])
    assert losses["int4"] < 5.6  # trains (init loss ~ ln 256 = 5.55)
    assert abs(losses["int4"] - losses["fp32"]) < 0.3


@needs_8_devices
def test_int4_comms_mesh_resume_bit_exact(tmp_path):
    """int4-transport SR training on a (2,4) mesh: save -> restore onto a
    fresh mesh -> continue == uninterrupted, bit-exact — the transport key
    stream is a pure function of the checkpointed (base key, step)."""
    opt = make_optimizer("production4bit", 3e-3)
    comms = CommsConfig(mode="int4")
    params, axes = init_model(jax.random.PRNGKey(0), _MICRO_CFG)
    key = jax.random.PRNGKey(11)

    mesh1 = jax.make_mesh((2, 4), ("data", "model"))
    state = make_train_state(params, opt, key=key)
    step1 = _comms_mesh_step(opt, mesh1, axes, state, comms)
    for t in range(2):
        state, metrics = step1(state, _batch(t))
    assert np.isfinite(float(metrics["loss"]))
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 2, state)
    uninterrupted = state
    for t in range(2, 4):
        uninterrupted, _ = step1(uninterrupted, _batch(t))

    mesh2 = jax.make_mesh((2, 4), ("data", "model"))
    target = jax.eval_shape(lambda: make_train_state(params, opt, key=key))
    shardings = train_state_shardings(target, axes, mesh2, zero=True)
    restored, _ = restore_checkpoint(d, target, shardings=shardings)
    step2 = _comms_mesh_step(opt, mesh2, axes, restored, comms)
    for t in range(2, 4):
        restored, _ = step2(restored, _batch(t))
    _assert_states_bitwise(restored, uninterrupted, "int4-comms mesh resume")


@needs_8_devices
def test_int4_comms_elastic_restore_close(tmp_path):
    """(2,4) -> (4,2) elastic restore under int4 transport: the comms
    transform itself is mesh-invariant (bit-equality test above), but the
    data-parallel loss reduction upstream legitimately reorders, so end to
    end this asserts close + finite with bounded outliers — the same
    contract the fp32-collective elastic test pins down."""
    opt = make_optimizer("production4bit", 3e-3)
    comms = CommsConfig(mode="int4")
    params, axes = init_model(jax.random.PRNGKey(0), _MICRO_CFG)
    key = jax.random.PRNGKey(11)
    mesh1 = jax.make_mesh((2, 4), ("data", "model"))
    state = make_train_state(params, opt, key=key)
    step1 = _comms_mesh_step(opt, mesh1, axes, state, comms)
    for t in range(2):
        state, _ = step1(state, _batch(t))
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 2, state)
    ref, _ = step1(state, _batch(2))

    mesh2 = jax.make_mesh((4, 2), ("data", "model"))
    target = jax.eval_shape(lambda: make_train_state(params, opt, key=key))
    shardings = train_state_shardings(target, axes, mesh2, zero=True)
    restored, _ = restore_checkpoint(d, target, shardings=shardings)
    step2 = _comms_mesh_step(opt, mesh2, axes, restored, comms)
    cont, metrics = step2(restored, _batch(2))
    assert np.isfinite(float(metrics["loss"]))
    for a, b in zip(
        jax.tree_util.tree_leaves(ref.params), jax.tree_util.tree_leaves(cont.params)
    ):
        diff = np.abs(np.asarray(a) - np.asarray(b))
        # Transport quantization snaps the (legitimate) reduction-order
        # perturbation to whole code bins, so the outlier fraction runs a
        # few x higher than the fp32-collective elastic case — bound it at
        # 1% with the same magnitude cap.
        assert float(np.mean(diff > 5e-4)) < 1e-2, float(np.mean(diff > 5e-4))
        assert float(diff.max()) < 5e-3, float(diff.max())
