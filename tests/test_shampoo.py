"""4-bit Shampoo (ISSUE 10): exact math, parity vs the fp32 oracle, state
representation, factor-memory ratio, and the kernel-route contract.

``shampoo32`` is the trajectory-parity oracle; ``shampoo4bit`` is the same
chain with the four Kronecker-factor trees held as 4-bit B128/Dyn
``QuantizedTensor``s and the grafting moments on the paper's 4-bit AdamW
recipe.  Parity is convergence-style (like the AdamW 4-bit tests): the
zero-excluding linear v-map damps the earliest steps identically across the
whole 4-bit family, so per-step closeness is not the contract — reaching the
optimum is.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.optimizers import (
    FACTOR_4BIT,
    adamw32,
    make_optimizer,
    optimizer_names,
    scale_by_shampoo,
    shampoo32,
    shampoo4bit,
    state_nbytes,
)
from repro.core.optimizers.transform import FusedAdamWRoute, Replace
from repro.core.quantizer import QuantizedTensor

jax.config.update("jax_platform_name", "cpu")


def _params(shape=(16, 512), seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=shape).astype(np.float32) * 0.1)}


def _quadratic_loss(params, target):
    return 0.5 * jnp.sum((params["w"] - target) ** 2)


def _run_steps(opt, params, target, steps):
    state = opt.init(params)
    upd = jax.jit(opt.update)
    losses = []
    for _ in range(steps):
        loss, grads = jax.value_and_grad(_quadratic_loss)(params, target)
        params, state = upd(grads, state, params)
        losses.append(float(loss))
    return params, state, losses


# ---------------------------------------------------------------------------
# exact math: one single-block leaf vs a numpy hand reference
# ---------------------------------------------------------------------------


def test_scale_by_shampoo_matches_hand_reference():
    b1, b2, eps, ridge, floor_rel = 0.9, 0.999, 1e-8, 1e-6, 0.01
    rng = np.random.default_rng(7)
    g_all = [rng.normal(size=(8, 8)).astype(np.float64) for _ in range(3)]

    # numpy reference: one 8x8 block, recompute every step
    m = np.zeros((8, 8))
    v = np.zeros((8, 8))
    sl = np.zeros((8, 8))
    sr = np.zeros((8, 8))

    def inv_quarter_root(s):
        w, u = np.linalg.eigh(s + ridge * np.eye(8))
        w = np.maximum(w, np.maximum(ridge, floor_rel * w.max()))
        return (u * w**-0.25) @ u.T

    refs = []
    for t, g in enumerate(g_all, start=1):
        bc1, bc2 = 1 - b1**t, 1 - b2**t
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        adam_dir = (m / bc1) / (np.sqrt(v / bc2) + eps)
        sl = b2 * sl + (1 - b2) * g @ g.T
        sr = b2 * sr + (1 - b2) * g.T @ g
        pl, pr = inv_quarter_root(sl / bc2), inv_quarter_root(sr / bc2)
        d = pl @ (m / bc1) @ pr
        refs.append(d * np.linalg.norm(adam_dir) / (np.linalg.norm(d) + 1e-30))

    tx = scale_by_shampoo(b1=b1, b2=b2, eps=eps, block_size=8, precond_every=1,
                          matrix_eps=ridge, floor_rel=floor_rel)
    params = {"w": jnp.zeros((8, 8), jnp.float32)}
    state = tx.init(params)
    for g, ref in zip(g_all, refs):
        u, state = tx.update({"w": jnp.asarray(g, jnp.float32)}, state, params)
        np.testing.assert_allclose(np.asarray(u["w"]), ref, rtol=2e-3, atol=2e-5)


def test_precond_recomputed_on_schedule():
    tx = scale_by_shampoo(block_size=8, precond_every=3)
    params = {"w": jnp.zeros((8, 8), jnp.float32)}
    state = tx.init(params)
    rng = np.random.default_rng(0)
    changed = []
    for _ in range(5):
        g = {"w": jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)}
        prev = np.asarray(state.precond_l["w"])
        _, state = tx.update(g, state, params)
        changed.append(not np.array_equal(np.asarray(state.precond_l["w"]), prev))
    # recompute when (count-1) % 3 == 0 -> counts 1 and 4
    assert changed == [True, False, False, True, False]
    # stats keep accumulating every step regardless
    assert float(jnp.sum(jnp.abs(state.stats_l["w"]))) > 0.0


def test_vector_params_fall_back_to_adam_direction():
    b1, b2, eps = 0.9, 0.999, 1e-8
    tx = scale_by_shampoo(b1=b1, b2=b2, eps=eps)
    params = {"b": jnp.zeros((32,), jnp.float32)}
    state = tx.init(params)
    assert state.stats_l["b"].shape == (0,)  # empty placeholder, not a factor
    g = {"b": jnp.asarray(np.random.default_rng(1).normal(size=(32,)), jnp.float32)}
    u, state = tx.update(g, state, params)
    mh = np.asarray(g["b"])  # t=1: m/bc1 == g, v/bc2 == g^2
    np.testing.assert_allclose(
        np.asarray(u["b"]), mh / (np.abs(mh) + eps), rtol=1e-5
    )
    assert state.stats_l["b"].shape == (0,)


def test_preconditioning_changes_the_direction():
    # the graft preserves the AdamW step NORM but not its direction — assert
    # Shampoo actually steers (i.e. the second-order path isn't an identity)
    params = _params((16, 512), seed=3)
    target = jnp.zeros_like(params["w"])
    p_sh, _, _ = _run_steps(shampoo32(1e-2), params, target, 5)
    p_ad, _, _ = _run_steps(adamw32(1e-2), params, target, 5)
    assert not np.allclose(np.asarray(p_sh["w"]), np.asarray(p_ad["w"]), atol=1e-5)


# ---------------------------------------------------------------------------
# trajectory parity: shampoo4bit vs the fp32 oracle (convergence-style)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["shampoo32", "shampoo4bit"])
def test_shampoo_converges_on_quadratic(name):
    params = _params((16, 512), seed=1)
    target = jnp.ones_like(params["w"]) * 0.5
    opt = make_optimizer(name, 2e-2, weight_decay=0.0)
    _, _, low = _run_steps(opt, params, target, 250)
    assert np.isfinite(low).all()
    assert low[-1] < 0.02 * low[0]


def test_shampoo4bit_tracks_fp32_oracle():
    params = _params((16, 512), seed=2)
    target = jnp.ones_like(params["w"]) * 0.5
    _, _, base = _run_steps(make_optimizer("shampoo32", 2e-2, weight_decay=0.0),
                            params, target, 250)
    _, _, low = _run_steps(make_optimizer("shampoo4bit", 2e-2, weight_decay=0.0),
                           params, target, 250)
    # same tolerance style as the 4-bit AdamW parity tests: both reach the
    # optimum; the 4-bit end point is within a small absolute gap
    assert low[-1] < 0.02 * low[0]
    assert abs(low[-1] - base[-1]) < 0.02 * low[0]


# ---------------------------------------------------------------------------
# state representation & memory (Tab. 4-style structural claims)
# ---------------------------------------------------------------------------


def test_4bit_factors_are_quantized_and_placeholders_stay_raw():
    params = {"w": jnp.zeros((256, 512)), "b": jnp.zeros((8192,))}
    s = make_optimizer("shampoo4bit", 1e-3).init(params)
    for field in ("stats_l", "stats_r", "precond_l", "precond_r"):
        leaf = s[field]["w"]
        assert isinstance(leaf, QuantizedTensor), field
        assert leaf.config.mapping == "dynamic" and leaf.config.bits == 4
        # vector params: (0,) placeholder, protected by min_ndim=2 — raw
        assert not isinstance(s[field]["b"], QuantizedTensor)
        assert s[field]["b"].shape == (0,)
    # grafting moments follow the paper's 4-bit AdamW recipe
    assert s["m"]["w"].config.normalization == "blockwise"
    assert s["v"]["w"].config.normalization == "rank1"
    assert isinstance(s["m"]["b"], QuantizedTensor)  # 8192 > threshold


def test_factor_bytes_cut_at_least_4x():
    params = {"w": jnp.zeros((256, 512)), "w2": jnp.zeros((512, 384))}
    s4 = make_optimizer("shampoo4bit", 1e-3).init(params)
    s32 = make_optimizer("shampoo32", 1e-3).init(params)

    def factor_bytes(s):
        return sum(
            state_nbytes(s[f]) for f in ("stats_l", "stats_r", "precond_l", "precond_r")
        )

    b4, b32 = factor_bytes(s4), factor_bytes(s32)
    assert b32 > 0 and b4 * 4 <= b32
    # and eval_shape sees the same structure (the drift gate runs structurally)
    s4_shape = jax.eval_shape(make_optimizer("shampoo4bit", 1e-3).init, params)
    assert factor_bytes(s4_shape) == b4


# ---------------------------------------------------------------------------
# kernel-route contract (pinned from shampoo.py's docstring)
# ---------------------------------------------------------------------------


def test_graft_moments_keep_kernel_eligible_layout_but_no_route_attached():
    # (32, 512): > threshold, ndim >= 2, last dim % 256 == 0 — kernel-shaped
    params = {"w": jnp.zeros((32, 512), jnp.float32)}
    opt = make_optimizer("shampoo4bit", 1e-3)
    state = opt.init(params)

    # 1) the m/v layout is ELIGIBLE for the fused AdamW route (so a future
    #    preconditioned kernel needs no state migration) ...
    route = FusedAdamWRoute(lr=1e-3)
    comp = {"m": state["m"]["w"], "v": state["v"]["w"]}
    assert route.eligible(comp, params["w"])

    # 2) ... but shampoo4bit attaches NO route: a whole-step Replace would
    #    silently drop the preconditioning.  The update stream must therefore
    #    contain ordinary additive leaves only.
    g = {"w": jnp.ones((32, 512), jnp.float32) * 0.01}
    new_params, _ = jax.jit(opt.update)(g, state, params)
    assert not isinstance(new_params["w"], Replace)
    assert new_params["w"].shape == (32, 512)
    assert bool(jnp.all(jnp.isfinite(new_params["w"])))
    assert not np.allclose(np.asarray(new_params["w"]), np.asarray(params["w"]))


def test_shampoo_registered_in_optimizer_specs():
    names = optimizer_names()
    assert "shampoo32" in names and "shampoo4bit" in names
    # sr variant constructs and steps
    opt = make_optimizer("shampoo4bit", 1e-3, stochastic_rounding=True)
    params = _params((16, 512))
    state = opt.init(params)
    g = jax.tree_util.tree_map(jnp.ones_like, params)
    p2, _ = opt.update(g, state, params, key=jax.random.PRNGKey(0))
    assert bool(jnp.all(jnp.isfinite(p2["w"])))
