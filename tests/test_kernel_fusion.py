"""Single-launch 3-d-grid fused kernel: stacked-leaf bit-exactness + trace gates.

Two invariants, both CI-enforced in the ``kernel-parity`` matrix:

1. **Bit-exactness** — ``fused_adamw4_leaf`` on a stacked ``(L, R, C)`` leaf
   must produce codes/scales/params bit-identical to the FROZEN historical
   per-slice implementation (one 2-d launch / oracle call per leading-dim
   slice, per-slice keys from sequential ``fold_in``), for RTN and SR,
   L in {1, 3, 8}, on both the ``ref`` and ``interpret`` backends.
2. **Trace size** — an ndim>=3 leaf traces exactly ONE ``pallas_call``
   (kernel backends), and the ``ref`` backend's equation count is independent
   of L (vmap, not Python unrolling).  This is the regression gate for the
   ROADMAP "fuse the stacked-leaf loop" item: a reintroduced per-slice loop
   fails here, not on a TPU.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.optimizers.adamw import M_4BIT, V_4BIT
from repro.core.quantizer import quantize
from repro.kernels import ops, ref
from repro.kernels.adamw4bit import fused_adamw4
from repro.kernels.sr import key_words

jax.config.update("jax_platform_name", "cpu")

HP = dict(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01)
LR, BC1, BC2 = 1e-3, 0.1, 0.001


def _rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32) * scale)


def _mk_leaf(L, R=16, C=256, sr=False, seed=0):
    m_cfg = dataclasses.replace(M_4BIT, stochastic_rounding=sr)
    v_cfg = dataclasses.replace(V_4BIT, stochastic_rounding=sr)
    p = _rand((L, R, C), seed, 0.1)
    g = _rand((L, R, C), seed + 1, 0.01)
    m_q = quantize(_rand((L, R, C), seed + 2, 0.01), m_cfg)
    v_q = quantize(jnp.abs(_rand((L, R, C), seed + 3, 0.001)) + 1e-10, v_cfg)
    return p, g, m_q, v_q


def _frozen_per_slice_leaf(p, g, m_s, v_s, backend, key):
    """The pre-fusion ``ops.fused_adamw4_leaf``, frozen verbatim: a Python
    ``for l in range(L)`` loop of 2-d launches (interpret) / oracle calls
    (ref), slice keys from sequential ``fold_in(leaf_key, l)``.  The new
    single-launch path must reproduce its outputs bit-for-bit."""
    shape = p.shape
    R, C = shape[-2], shape[-1]
    L = p.size // (R * C)
    use_sr = bool(m_s.config.stochastic_rounding) and key is not None
    m_table, v_table = m_s.config.table(), v_s.config.table()
    lr, bc1, bc2 = jnp.float32(LR), jnp.float32(BC1), jnp.float32(BC2)

    p3 = p.reshape(L, R, C)
    g3 = g.astype(jnp.float32).reshape(L, R, C)
    m_packed = m_s.codes.reshape(L, R, C // 2)
    m_scale = m_s.scales[0].reshape(L, R, C // 128)
    v_packed = v_s.codes.reshape(L, R, C // 2)
    v_r, v_c = ops._rank1_slice_stats(v_s.scales, shape)

    v_old = jnp.stack(
        [ref.dequant_rank1(v_packed[l], v_r[l], v_c, v_table) for l in range(L)]
    )
    v_new = HP["b2"] * v_old + (1.0 - HP["b2"]) * g3 * g3
    new_stats = ops._rank1_new_stats(v_new.reshape(shape))
    v_r_new, v_c_new = ops._rank1_slice_stats(new_stats, shape)

    slice_keys = (
        [key_words(jax.random.fold_in(key, l)) for l in range(L)]
        if use_sr
        else [None] * L
    )

    outs = []
    for l in range(L):
        if backend == "ref":
            if use_sr:
                o = ref.fused_adamw4_sr_reference(
                    p3[l], g3[l], m_packed[l], m_scale[l], v_packed[l],
                    v_r[l], v_c, m_table, v_table,
                    lr, HP["b1"], HP["b2"], HP["eps"], HP["weight_decay"],
                    bc1, bc2, jnp.stack(slice_keys[l]), v_r_new[l], v_c_new,
                )[:4]
            else:
                o = ref.fused_adamw4_reference(
                    p3[l], g3[l], m_packed[l], m_scale[l], v_packed[l],
                    v_r[l], v_c, m_table, v_table,
                    lr, HP["b1"], HP["b2"], HP["eps"], HP["weight_decay"],
                    bc1, bc2, v_r_new[l], v_c_new,
                )[:4]
        else:
            seed = jnp.stack(slice_keys[l]) if use_sr else None
            o = fused_adamw4(
                p3[l], g3[l], m_packed[l], m_scale[l], v_packed[l],
                v_r[l], v_c, v_r_new[l], v_c_new,
                m_table, v_table, lr, bc1, bc2, seed,
                interpret=True, use_sr=use_sr, **HP,
            )
        outs.append(o)
    w3, mp3, ms3, vp3 = (jnp.stack(x) for x in zip(*outs))
    return w3.reshape(shape), mp3, ms3, vp3


def _run_new_leaf(p, g, m_q, v_q, key):
    return ops.fused_adamw4_leaf(
        p, g, m_q, v_q, jnp.float32(LR),
        HP["b1"], HP["b2"], HP["eps"], HP["weight_decay"],
        jnp.float32(BC1), jnp.float32(BC2), key=key,
    )


def _assert_bits_equal(a, b):
    """Bitwise equality, floats included (uint32 view — not just allclose)."""
    a, b = np.asarray(a), np.asarray(b)
    assert a.shape == b.shape and a.dtype == b.dtype
    if a.dtype == np.float32:
        np.testing.assert_array_equal(a.view(np.uint32), b.view(np.uint32))
    else:
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# stacked-leaf bit-exactness: new single-launch vs frozen per-slice loop
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["ref", "interpret"])
@pytest.mark.parametrize("use_sr", [False, True], ids=["rtn", "sr"])
@pytest.mark.parametrize("L", [1, 3, 8])
def test_stacked_leaf_bit_identical_to_per_slice_loop(
    monkeypatch, backend, use_sr, L
):
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", backend)
    p, g, m_q, v_q = _mk_leaf(L, sr=use_sr, seed=11 * L)
    key = jax.random.PRNGKey(7) if use_sr else None

    w_new, m2, v2 = _run_new_leaf(p, g, m_q, v_q, key)
    fw, fmp, fms, fvp = _frozen_per_slice_leaf(p, g, m_q, v_q, backend, key)

    _assert_bits_equal(w_new, fw)
    _assert_bits_equal(m2.codes, fmp.reshape(m2.codes.shape))
    _assert_bits_equal(m2.scales[0], fms.reshape(m2.scales[0].shape))
    _assert_bits_equal(v2.codes, fvp.reshape(v2.codes.shape))


def test_2d_leaf_unchanged(monkeypatch):
    """Plain 2-d leaves (no stacking) ride the same single launch, outputs
    bit-identical to the historical 2-d path."""
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "interpret")
    p3, g3, m_q3, v_q3 = _mk_leaf(1, sr=True, seed=5)
    p, g = p3[0], g3[0]
    m_q = quantize(
        _rand((1, 16, 256), 7, 0.01)[0],
        dataclasses.replace(M_4BIT, stochastic_rounding=True),
    )
    v_q = quantize(
        jnp.abs(_rand((1, 16, 256), 8, 0.001))[0] + 1e-10,
        dataclasses.replace(V_4BIT, stochastic_rounding=True),
    )
    key = jax.random.PRNGKey(3)
    w_new, m2, v2 = _run_new_leaf(p, g, m_q, v_q, key)
    fw, fmp, fms, fvp = _frozen_per_slice_leaf(p, g, m_q, v_q, "interpret", key)
    _assert_bits_equal(w_new, fw)
    _assert_bits_equal(m2.codes, fmp.reshape(m2.codes.shape))
    _assert_bits_equal(v2.codes, fvp.reshape(v2.codes.shape))


# ---------------------------------------------------------------------------
# trace-size regression gates (the CI single-launch invariant)
# ---------------------------------------------------------------------------


def _leaf_jaxpr(L, R, C, sr, backend, monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", backend)
    p, g, m_q, v_q = _mk_leaf(L, R, C, sr=sr, seed=1)
    if sr:
        fn = lambda p, g, key: _run_new_leaf(p, g, m_q, v_q, key)
        return jax.make_jaxpr(fn)(p, g, jax.random.PRNGKey(0))
    fn = lambda p, g: _run_new_leaf(p, g, m_q, v_q, None)
    return jax.make_jaxpr(fn)(p, g)


@pytest.mark.parametrize("use_sr", [False, True], ids=["rtn", "sr"])
def test_stacked_leaf_single_pallas_launch(monkeypatch, use_sr):
    """The acceptance gate: an (8, 256, 512) leaf issues exactly ONE
    pallas_call — L x launch overhead and L-unrolled jaxprs are regressions."""
    jaxpr = _leaf_jaxpr(8, 256, 512, use_sr, "interpret", monkeypatch)
    assert ops.count_pallas_calls(jaxpr) == 1, jaxpr


def test_ref_backend_trace_is_depth_independent(monkeypatch):
    """The ref backend vmaps the oracle: equation count must not grow with L
    (and it never launches a kernel)."""
    counts = {}
    for L in (1, 8):
        jaxpr = _leaf_jaxpr(L, 16, 256, True, "ref", monkeypatch)
        assert ops.count_pallas_calls(jaxpr) == 0
        counts[L] = ops.jaxpr_eqn_count(jaxpr)
    assert counts[1] == counts[8], counts


def test_4d_leaf_single_launch_and_bit_exact(monkeypatch):
    """ndim>3 stacked leaves (e.g. (G, L, R, C) grouped stacks) flatten their
    leading dims into the one 3-d grid too."""
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "interpret")
    m_cfg = dataclasses.replace(M_4BIT, stochastic_rounding=True)
    v_cfg = dataclasses.replace(V_4BIT, stochastic_rounding=True)
    p = _rand((2, 3, 16, 256), 21, 0.1)
    g = _rand((2, 3, 16, 256), 22, 0.01)
    m_q = quantize(_rand((2, 3, 16, 256), 23, 0.01), m_cfg)
    v_q = quantize(jnp.abs(_rand((2, 3, 16, 256), 24, 0.001)) + 1e-10, v_cfg)
    key = jax.random.PRNGKey(9)

    jaxpr = jax.make_jaxpr(
        lambda p, g, key: _run_new_leaf(p, g, m_q, v_q, key)
    )(p, g, key)
    assert ops.count_pallas_calls(jaxpr) == 1

    w_new, m2, v2 = _run_new_leaf(p, g, m_q, v_q, key)
    fw, fmp, fms, fvp = _frozen_per_slice_leaf(p, g, m_q, v_q, "interpret", key)
    _assert_bits_equal(w_new, fw)
    _assert_bits_equal(m2.codes, fmp.reshape(m2.codes.shape))
    _assert_bits_equal(v2.codes, fvp.reshape(v2.codes.shape))
