"""Substrate tests: checkpoint/restore, data determinism, fault tolerance,
serve engine, end-to-end train loop with the 4-bit optimizer."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.optimizers import adamw4bit, state_nbytes
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import init_model, loss_fn
from repro.serve.engine import Request, ServeEngine
from repro.train.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.train.fault_tolerance import (
    HostMonitor,
    StragglerDetector,
    plan_elastic,
    run_with_recovery,
)
from repro.train.train_loop import TrainState, build_train_step, make_train_state

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def _tiny_state():
    cfg = reduced_config("internlm2-1.8b")
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    opt = adamw4bit(1e-3)
    return cfg, opt, make_train_state(params, opt)


def test_checkpoint_roundtrip_quantized_state(tmp_path):
    cfg, opt, state = _tiny_state()
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 7, state, extra={"note": "hi"})
    assert latest_step(d) == 7
    restored, extra = restore_checkpoint(d, jax.eval_shape(lambda: state))
    assert extra == {"note": "hi"}
    for a, b in zip(
        jax.tree_util.tree_leaves(state), jax.tree_util.tree_leaves(restored)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_detects_corruption(tmp_path):
    cfg, opt, state = _tiny_state()
    d = str(tmp_path / "ckpt")
    path = save_checkpoint(d, 1, state)
    # corrupt this host's shard file (v2 format: per-host .bin + COMMIT)
    bin_path = os.path.join(path, "host_00000.bin")
    data = bytearray(open(bin_path, "rb").read())
    data[len(data) // 2] ^= 0xFF
    open(bin_path, "wb").write(bytes(data))
    with pytest.raises(Exception):
        restore_checkpoint(d, jax.eval_shape(lambda: state))


def test_checkpoint_detects_corruption_legacy_npz(tmp_path):
    """The v1 single-file format stays readable — and stays hash-checked."""
    cfg, opt, state = _tiny_state()
    d = str(tmp_path / "ckpt")
    path = save_checkpoint(d, 1, state, fmt_version="npz")
    npz_path = os.path.join(path, "arrays.npz")
    data = bytearray(open(npz_path, "rb").read())
    data[len(data) // 2] ^= 0xFF
    open(npz_path, "wb").write(bytes(data))
    with pytest.raises(Exception):
        restore_checkpoint(d, jax.eval_shape(lambda: state))


def test_checkpoint_manager_keep_k_and_async(tmp_path):
    cfg, opt, state = _tiny_state()
    mgr = CheckpointManager(str(tmp_path / "ckpt"), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    mgr.wait()
    steps = sorted(
        n for n in os.listdir(tmp_path / "ckpt") if n.startswith("step_")
    )
    assert steps == ["step_00000003", "step_00000004"]


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_data_deterministic_and_elastic():
    cfg = DataConfig(vocab_size=512, seq_len=32, global_batch=8, seed=3)
    stream = SyntheticLM(cfg)
    a = stream.batch_at(5, host=0, num_hosts=1)
    b = stream.batch_at(5, host=0, num_hosts=1)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # elastic: different host counts give valid shapes, host shards disjoint-ish
    h0 = stream.batch_at(5, host=0, num_hosts=2)
    h1 = stream.batch_at(5, host=1, num_hosts=2)
    assert h0["tokens"].shape == (4, 32) and h1["tokens"].shape == (4, 32)
    assert not np.array_equal(h0["tokens"], h1["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])
    assert (a["labels"][:, -1] == -1).all()


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------


def test_straggler_detector_flags_slow_host():
    det = StragglerDetector(threshold=1.5, window=8, patience=2)
    for step in range(8):
        for h in range(4):
            det.record(h, 1.0 if h != 2 else 3.0)
        flagged = det.stragglers()
    assert flagged == [2]


def test_host_monitor_deadline():
    t = [0.0]
    mon = HostMonitor([0, 1, 2], deadline_s=10.0, clock=lambda: t[0])
    t[0] = 5.0
    mon.beat(0)
    mon.beat(1)
    t[0] = 12.0
    assert mon.dead_hosts() == [2]
    plan = plan_elastic(mon.alive(), latest_checkpoint=40)
    assert plan.num_hosts == 2 and plan.restore_step == 40
    assert plan.host_index(1) == 1


def test_run_with_recovery_replays_from_checkpoint():
    ckpts = []
    failed = {30: False}

    def train_one(step):
        return 1.0 / (step + 1)

    def save(step):
        ckpts.append(step)

    def restore_latest():
        return ckpts[-1] if ckpts else 0

    def injector(step):
        if step == 30 and not failed[30]:
            failed[30] = True
            return True
        return False

    losses, restarts, replayed = run_with_recovery(
        50, train_one, save, restore_latest, checkpoint_every=10,
        failure_injector=injector,
    )
    assert restarts == 1
    assert replayed == 0  # failed exactly at a checkpoint boundary
    assert len(losses) == 50


# ---------------------------------------------------------------------------
# end-to-end: train -> checkpoint -> crash -> restore -> loss continuity
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_train_restore_continuity(tmp_path):
    cfg = reduced_config("internlm2-1.8b")
    params, axes = init_model(jax.random.PRNGKey(0), cfg)
    opt = adamw4bit(5e-3)
    state = make_train_state(params, opt)
    step_fn = jax.jit(build_train_step(cfg, opt))
    data = SyntheticLM(DataConfig(cfg.vocab_size, 32, 4, seed=1))

    losses = []
    for t in range(6):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(t).items()}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if t == 2:
            save_checkpoint(str(tmp_path / "c"), 3, state)

    # "crash" and restore at step 3, replay steps 3..5 — identical losses
    restored, _ = restore_checkpoint(
        str(tmp_path / "c"), jax.eval_shape(lambda: state)
    )
    replay = []
    state2 = restored
    for t in range(3, 6):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(t).items()}
        state2, metrics = step_fn(state2, batch)
        replay.append(float(metrics["loss"]))
    np.testing.assert_allclose(replay, losses[3:], rtol=1e-5)


# ---------------------------------------------------------------------------
# serve engine
# ---------------------------------------------------------------------------


def test_serve_engine_continuous_batching():
    cfg = reduced_config("internlm2-1.8b")
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_batch=2, s_max=256)
    reqs = [
        Request(rid=i, prompt=[1 + i, 2 + i, 3 + i], max_new_tokens=4)
        for i in range(5)  # more requests than slots -> queueing
    ]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r in reqs:
        assert r.done
        assert len(r.output) == 4
        assert all(0 <= t < cfg.vocab_size for t in r.output)


def test_serve_engine_greedy_determinism():
    cfg = reduced_config("internlm2-1.8b")
    params, _ = init_model(jax.random.PRNGKey(0), cfg)

    def gen():
        eng = ServeEngine(cfg, params, max_batch=2, s_max=256)
        r = Request(rid=0, prompt=[5, 6, 7], max_new_tokens=5)
        eng.submit(r)
        eng.run()
        return r.output

    assert gen() == gen()
