"""Test-process setup.

Forces host (CPU) devices BEFORE any jax import so mesh/sharding tests can
exercise real multi-device layouts (2x4, 4x2, 8x1) in-process.  The count
defaults to 8 and is overridable with REPRO_FORCE_DEVICES — the CI
checkpoint matrix runs the roundtrip/resume suites under both 1 and 8
devices so the single-device and sharded I/O code paths both gate every PR.
Single-device tests are unaffected: unsharded computations run on device 0
as before; tests that need >=8 devices skip themselves under a forced
single-device run.
"""

import os

_n = os.environ.get("REPRO_FORCE_DEVICES", "8")
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_n}"
    ).strip()
