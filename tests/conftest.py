"""Test-process setup.

Forces 8 host (CPU) devices BEFORE any jax import so mesh/sharding tests can
exercise real multi-device layouts (2x4, 4x2, 8x1) in-process.  Single-device
tests are unaffected: unsharded computations run on device 0 as before.
"""

import os

if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8"
    ).strip()
