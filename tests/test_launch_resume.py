"""Regression tests for the training CLI's elastic-resume path.

The old restore built its target as ``jax.eval_shape(lambda: state)`` — which
requires a fully *allocated* ``state`` to close over, so a resuming process
paid for the model twice (fresh init + restored copy).  The fixed path
(``abstract_train_state``) runs the entire init under ``eval_shape``: every
target leaf is a ShapeDtypeStruct and restore allocates exactly one copy.
"""

import pathlib
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.optimizers import make_optimizer
from repro.launch.train import abstract_train_state
from repro.train.checkpoint import restore_checkpoint, save_checkpoint
from repro.train.train_loop import make_train_state

jax.config.update("jax_platform_name", "cpu")


def test_abstract_train_state_allocates_nothing():
    cfg = reduced_config("internlm2-1.8b")
    opt = make_optimizer("production4bit", 1e-3)
    target, axes = abstract_train_state(cfg, opt, key=jax.random.PRNGKey(0))
    leaves = jax.tree_util.tree_leaves(target)
    assert leaves, "abstract state is empty"
    for l in leaves:
        assert isinstance(l, jax.ShapeDtypeStruct), type(l)
    assert isinstance(axes, dict) and "embed" in axes


def test_abstract_target_restores_real_checkpoint(tmp_path):
    cfg = reduced_config("internlm2-1.8b")
    opt = make_optimizer("adamw4bit", 1e-3)
    from repro.models import init_model

    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(3)
    state = make_train_state(params, opt, key=key)
    d = str(tmp_path / "c")
    save_checkpoint(d, 1, state)

    target, _ = abstract_train_state(cfg, opt, key=key)
    restored, _ = restore_checkpoint(d, target)
    for a, b in zip(
        jax.tree_util.tree_leaves(state), jax.tree_util.tree_leaves(restored)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_cli_train_checkpoint_resume(tmp_path):
    """The CLI end-to-end: train 4 steps with checkpoints, rerun to 8 steps
    — the second process must resume (not restart) and finish cleanly."""
    d = str(tmp_path / "ckpt")
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "internlm2-1.8b", "--reduced",
        "--optimizer", "production4bit", "--sr-seed", "0",
        "--batch", "4", "--seq", "32", "--ckpt-dir", d, "--ckpt-every", "2",
    ]
    repo_root = str(pathlib.Path(__file__).resolve().parents[1])
    env = {"PYTHONPATH": str(pathlib.Path(repo_root) / "src"),
           "PATH": "/usr/bin:/bin:/usr/local/bin",
           "JAX_PLATFORMS": "cpu"}
    r1 = subprocess.run(cmd + ["--steps", "4"], capture_output=True, text=True,
                        env=env, cwd=repo_root, timeout=420)
    assert r1.returncode == 0, r1.stderr[-2000:]
    r2 = subprocess.run(cmd + ["--steps", "8"], capture_output=True, text=True,
                        env=env, cwd=repo_root, timeout=420)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed from step 4" in r2.stdout, r2.stdout[-2000:]
