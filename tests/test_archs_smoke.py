"""Per-arch smoke tests: reduced config, one forward + 4-bit-AdamW train step
on CPU, asserting output shapes and no NaNs (the deliverable-f requirement).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # compiles one train step per architecture

from repro.configs import ARCHS, reduced_config
from repro.core.optimizers import adamw4bit
from repro.models import decode_step, init_model, init_serve_cache, loss_fn

jax.config.update("jax_platform_name", "cpu")

B, S = 2, 32


def _batch(cfg, key):
    ks = jax.random.split(key, 4)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
    }
    if cfg.input_mode == "embeds":
        batch["embeds"] = jax.random.normal(ks[2], (B, S, cfg.d_model)) * 0.02
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(ks[3], (B, S, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS.keys()))
def test_arch_smoke_train_step(arch):
    cfg = reduced_config(arch)
    params, axes = init_model(jax.random.PRNGKey(0), cfg)
    # axes tree mirrors params tree
    assert jax.tree_util.tree_structure(
        jax.tree_util.tree_map(lambda x: 0, params)
    ) == jax.tree_util.tree_structure(
        jax.tree_util.tree_map(
            lambda a: 0, axes,
            is_leaf=lambda a: isinstance(a, tuple) and all(isinstance(s, str) for s in a),
        )
    )

    batch = _batch(cfg, jax.random.PRNGKey(1))
    opt = adamw4bit(1e-3)
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch), has_aux=True
        )(params)
        params, state = opt.update(grads, state, params)
        return params, state, loss

    p1, s1, loss = step(params, state, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    assert float(loss) > 0
    # params actually moved
    delta = sum(
        float(jnp.sum(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(p1))
    )
    assert delta > 0, f"{arch}: no parameter movement"
    # second step continues from quantized state without NaN
    p2, s2, loss2 = step(p1, s1, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", sorted(ARCHS.keys()))
def test_arch_smoke_decode_step(arch):
    cfg = reduced_config(arch)
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    caches = init_serve_cache(cfg, B, s_max=256)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B,), 0, cfg.vocab_size)
    pos = jnp.zeros((B,), jnp.int32)
    enc_out = None
    if cfg.family == "encdec":
        enc_out = jax.random.normal(jax.random.PRNGKey(3), (B, 16, cfg.d_model)).astype(
            jnp.bfloat16
        )
    logits, new_caches = jax.jit(
        lambda p, c, t, q: decode_step(p, cfg, c, t, q, enc_out=enc_out)
    )(params, caches, tokens, pos)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
