"""Unit + property tests for the quantization core (mappings/norms/packing).

The property tests are seeded deterministic sweeps (not hypothesis-driven)
so the suite collects in environments without optional dev deps; the sweeps
cover the same edge cases the strategies used to draw (odd/even last dims,
singleton shapes, extreme scales).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mappings, normalization, packing
from repro.core.quantizer import (
    B128_DE,
    B2048_DE,
    RANK1_LINEAR,
    QuantConfig,
    dequantize,
    quantize,
)

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# mapping tables (paper App. E.2 ground truth)
# ---------------------------------------------------------------------------


def test_linear_unsigned_excludes_zero_and_matches_paper():
    t = np.asarray(mappings.mapping_table("linear", 4, signed=False))
    assert t.shape == (16,)
    assert t.min() == pytest.approx(0.0625)  # paper: smallest Linear value
    assert t.max() == 1.0
    assert 0.0 not in t
    np.testing.assert_allclose(t, (np.arange(16) + 1) / 16, rtol=1e-6)


def test_de_unsigned_corner_cases():
    t = np.asarray(mappings.mapping_table("de", 4, signed=False))
    assert t.shape == (16,)
    assert t[0] == 0.0 and t[-1] == 1.0
    # paper: smallest representable DE-0 value is 0.0033
    assert t[1] == pytest.approx(0.00325, abs=1e-6)


def test_de0_drops_zero_only():
    de = np.asarray(mappings.mapping_table("de", 4, signed=False))
    de0 = np.asarray(mappings.mapping_table("de0", 4, signed=False))
    assert de0.shape == (15,)
    np.testing.assert_allclose(de0, de[de != 0.0])


def test_de_signed_asymmetric():
    t = np.asarray(mappings.mapping_table("de", 4, signed=True))
    assert t.shape == (16,)
    assert 1.0 in t and -1.0 not in t  # App. E.2: -1 is not defined
    assert 0.0 in t


@pytest.mark.parametrize("kind", ["linear", "de", "de0"])
@pytest.mark.parametrize("signed", [False, True])
@pytest.mark.parametrize("bits", [4, 8])
def test_tables_sorted_unique_bounded(kind, signed, bits):
    t = np.asarray(mappings.mapping_table(kind, bits, signed))
    assert len(t) <= 2**bits
    assert (np.diff(t) > 0).all()
    assert t.max() <= 1.0 and t.min() >= (-1.0 if signed else 0.0)


def test_encode_is_round_to_nearest():
    t = mappings.mapping_table("de", 4, signed=True)
    n = jnp.linspace(-1, 1, 513)
    idx = mappings.encode(n, t)
    dec = mappings.decode(idx, t)
    # brute-force argmin oracle
    brute = jnp.argmin(jnp.abs(n[:, None] - t[None, :]), axis=1)
    np.testing.assert_allclose(
        np.abs(np.asarray(dec - n)),
        np.abs(np.asarray(jnp.take(t, brute) - n)),
        atol=1e-7,
    )


def test_stochastic_rounding_unbiased():
    t = mappings.mapping_table("linear", 4, signed=False)
    n = jnp.full((20000,), 0.7)  # between 0.6875 and 0.75
    key = jax.random.PRNGKey(0)
    codes = mappings.encode_stochastic(n, t, key)
    mean = float(jnp.mean(mappings.decode(codes, t)))
    assert abs(mean - 0.7) < 2e-3  # unbiased in expectation (Assumption 4)


# ---------------------------------------------------------------------------
# normalization
# ---------------------------------------------------------------------------


def test_blockwise_normalize_unit_interval():
    x = jnp.asarray(np.random.default_rng(0).normal(size=300).astype(np.float32))
    n, s = normalization.blockwise_normalize(x, 128)
    assert s.shape == (3,)  # ceil(300/128)
    assert float(jnp.max(jnp.abs(n))) <= 1.0 + 1e-6
    back = n * normalization.blockwise_denorm(s, x.shape, 128)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), rtol=1e-6)


def test_rank1_tighter_than_pertensor():
    # Outliers confined to one row: every column max hits the outlier, but the
    # row maxes of the other rows stay small, so min(r_i, c_j) rescues the
    # interior (paper Sec. 4.2 — works whichever single dim carries outliers).
    rng = np.random.default_rng(1)
    x = np.abs(rng.normal(size=(32, 48)).astype(np.float32)) * 0.01
    x[3, :] += 10.0
    n_r1, stats = normalization.rank1_normalize(jnp.asarray(x))
    n_pt, _ = normalization.pertensor_normalize(jnp.asarray(x))
    # interior elements are scaled by ~their own magnitude scale, not by the
    # global outlier: normalized values should be much larger (less crushed)
    interior = np.ones_like(x, dtype=bool)
    interior[3, :] = False
    assert float(jnp.mean(n_r1[interior])) > 5 * float(jnp.mean(n_pt[interior]))
    # exact reconstruction via denorm
    back = n_r1 * normalization.rank1_denorm(stats, x.shape)
    np.testing.assert_allclose(np.asarray(back), x, rtol=1e-5)


def test_rank1_3d_generalization():
    x = jnp.asarray(
        np.abs(np.random.default_rng(2).normal(size=(4, 8, 16))).astype(np.float32)
    )
    n, stats = normalization.rank1_normalize(x)
    assert len(stats) == 3
    assert stats[0].shape == (4,) and stats[1].shape == (8,) and stats[2].shape == (16,)
    assert float(jnp.max(n)) <= 1.0 + 1e-6


def test_all_zero_tensor_is_safe():
    x = jnp.zeros((16, 16))
    for cfg in (B128_DE, RANK1_LINEAR):
        xd = dequantize(quantize(x, cfg))
        assert bool(jnp.all(jnp.isfinite(xd)))


# ---------------------------------------------------------------------------
# packing
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "last, rows",
    # sweep: singleton, odd/even last dims, nibble boundaries, strategy maxima
    [(1, 1), (1, 5), (2, 3), (7, 2), (16, 1), (127, 4), (128, 2),
     (129, 3), (255, 1), (256, 5), (300, 3), (511, 2), (512, 4), (513, 5)],
)
def test_pack_unpack_roundtrip(last, rows):
    rng = np.random.default_rng(last * 7 + rows)
    codes = jnp.asarray(rng.integers(0, 16, size=(rows, last), dtype=np.uint8))
    packed = packing.pack4(codes)
    assert packed.shape == (rows, packing.packed_last_dim(last))
    out = packing.unpack4(packed, last)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(codes))


# ---------------------------------------------------------------------------
# quantizer round-trip properties (seeded deterministic sweep)
# ---------------------------------------------------------------------------

# (rows, cols, seed, scale): shapes span singleton through the old strategy
# maxima; scales span subnormal-adjacent (1e-8) through outlier (1e4).
TENSOR_SWEEP = [
    (1, 1, 0, 1.0),
    (1, 300, 1, 1e-3),
    (40, 1, 2, 1e4),
    (3, 7, 3, 1e-8),
    (17, 127, 4, 1.0),
    (16, 128, 5, 1e-3),
    (5, 129, 6, 1e4),
    (40, 300, 7, 1.0),
    (8, 256, 8, 1e-8),
    (31, 200, 9, 1e4),
]


def _sweep_tensor(rows, cols, seed, scale):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(rows, cols)).astype(np.float32) * scale)


@pytest.mark.parametrize("rows, cols, seed, scale", TENSOR_SWEEP)
def test_quantize_dequantize_bounded_error_signed(rows, cols, seed, scale):
    """Dequantized values stay within one scale unit of the original and the
    error is bounded by the coarsest table gap times the local scale."""
    x = _sweep_tensor(rows, cols, seed, scale)
    q = quantize(x, B128_DE)
    xd = dequantize(q)
    scale_t = normalization.blockwise_denorm(q.scales[0], x.shape, 128)
    # max relative-to-scale error bounded by half the largest table gap
    table = np.asarray(B128_DE.table())
    max_gap = np.max(np.diff(table))
    err = np.asarray(jnp.abs(xd - x) / scale_t)
    assert err.max() <= max_gap / 2 + 1e-5


@pytest.mark.parametrize("rows, cols, seed, scale", TENSOR_SWEEP)
def test_second_moment_never_zero(rows, cols, seed, scale):
    """Rank-1/Linear (paper's 2nd-moment quantizer) never emits exact zeros
    for a positive tensor — the zero-point problem fix."""
    x = _sweep_tensor(rows, cols, seed, scale)
    v = jnp.abs(x) + 1e-30
    q = quantize(v, RANK1_LINEAR)
    vd = dequantize(q)
    assert float(jnp.min(vd)) > 0.0


def test_quantized_bytes_accounting():
    x = jnp.zeros((1024, 1024))
    q4 = quantize(x, B128_DE)
    # 4-bit codes: n/2 bytes; scales: n/128 fp32
    assert q4.nbytes() == 1024 * 1024 // 2 + 1024 * 1024 // 128 * 4
    q8 = quantize(x, B2048_DE._replace_bits(8) if hasattr(B2048_DE, "_replace_bits") else QuantConfig(bits=8, normalization="blockwise", block_size=2048, mapping="de"))
    assert q8.nbytes() == 1024 * 1024 + 1024 * 1024 // 2048 * 4


def test_dequantize_under_jit_and_grad_free():
    x = jnp.asarray(np.random.default_rng(3).normal(size=(8, 256)).astype(np.float32))
    q = quantize(x, B128_DE)

    @jax.jit
    def f(qt):
        return jnp.sum(dequantize(qt))

    assert np.isfinite(float(f(q)))
