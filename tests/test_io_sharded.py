"""Sharded checkpoint I/O subsystem (repro.io): format v2 invariants.

The enforced contracts:
  * a sharded save writes per-host shard files and never materializes a full
    global array on any host (gather-spy over the writer's device->host seam
    on the 8-device harness), and restore assembles per-device regions only;
  * save -> restore is bit-exact across mesh layouts — packed 4-bit codes,
    scales, fp32 params alike — including 2x4 -> 4x2 elastic restore;
  * ``CheckpointManager.save`` returns before serialization completes
    (blocking only on the snapshot copy) and the COMMIT marker lands last;
  * retention GC keeps (keep_last ∪ keep_every-multiples ∪ newest) and
    sweeps crash leftovers;
  * the legacy v1 npz format stays readable behind the manifest's
    format-version switch.
"""

import json
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.optimizers import make_optimizer
from repro.core.quantizer import QuantizedTensor
from repro.io import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.io import format as ckfmt, reader, writer
from repro.models import LayerSpec, ModelConfig, init_model
from repro.train.train_loop import make_train_state, train_state_shardings

jax.config.update("jax_platform_name", "cpu")

needs_8_devices = pytest.mark.skipif(
    len(jax.devices()) < 8, reason="needs the 8-device host harness"
)

MICRO_CFG = ModelConfig(
    name="micro-lm",
    num_layers=1,
    d_model=64,
    num_heads=2,
    num_kv_heads=1,
    head_dim=32,
    d_ff=128,
    vocab_size=256,  # embed = 256*64 = 16384 elements > threshold -> quantized
    blocks=(LayerSpec("dense", 0),),
    remat=False,
)


def _nonzero_state(opt_name="production4bit"):
    """A TrainState with non-trivial quantized moments (2 eager update steps
    on synthetic grads — no jit compile, keeps the 1-device matrix leg fast)."""
    opt = make_optimizer(opt_name, 3e-3)
    params, axes = init_model(jax.random.PRNGKey(0), MICRO_CFG)
    state = make_train_state(params, opt, key=jax.random.PRNGKey(5))
    rng = np.random.default_rng(7)
    p, s = state.params, state.opt_state
    for t in range(2):
        grads = jax.tree_util.tree_map(
            lambda x: jnp.asarray(
                rng.normal(size=x.shape).astype(np.float32) * 0.02
            ),
            p,
        )
        p, s = opt.update(grads, s, p, key=jax.random.fold_in(state.key, t))
    from repro.train.train_loop import TrainState

    return TrainState(p, s, jnp.asarray(2, jnp.int32), state.key), axes


def _flat_with_keys(tree):
    return [
        (jax.tree_util.keystr(path), leaf)
        for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]
    ]


def _assert_trees_bitwise(a, b, what=""):
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb, f"{what}: tree structure mismatch"
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y), err_msg=what)


# ---------------------------------------------------------------------------
# format v2 on-disk schema
# ---------------------------------------------------------------------------


def test_manifest_v2_schema(tmp_path):
    tree = {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "n": jnp.asarray(3, jnp.int32)}
    d = str(tmp_path / "c")
    path = save_checkpoint(d, 5, tree, extra={"note": "hi"})
    names = sorted(os.listdir(path))
    assert names == ["COMMIT", "host_00000.bin", "index_host_00000.json",
                     "manifest.json"]
    manifest = json.load(open(os.path.join(path, "manifest.json")))
    assert manifest["format_version"] == 2
    assert manifest["step"] == 5 and manifest["extra"] == {"note": "hi"}
    assert manifest["num_hosts"] == 1 and "structure" in manifest
    by_key = {m["key"]: m for m in manifest["leaves"]}
    assert by_key["['w']"]["shape"] == [3, 4]
    assert by_key["['w']"]["dtype"] == "float32"
    idx = json.load(open(os.path.join(path, "index_host_00000.json")))
    assert idx["process"] == 0
    recs = idx["shards"]["['w']"]
    total = sum(r["nbytes"] for r in recs)
    assert total == 12 * 4
    for r in recs:
        assert len(r["index"]) == 2 and len(r["sha256"]) == 16
    assert latest_step(d) == 5


def test_incomplete_dir_ignored_and_fallback(tmp_path):
    """A save killed mid-shard-write (truncated bin, no COMMIT) is invisible
    to latest_step; restore lands on the last complete step."""
    tree = {"w": jnp.arange(64, dtype=jnp.float32)}
    d = str(tmp_path / "c")
    save_checkpoint(d, 5, tree)
    crashed = save_checkpoint(d, 9, tree)
    # simulate the kill: COMMIT never written, shard file cut short
    os.remove(os.path.join(crashed, "COMMIT"))
    bin_path = os.path.join(crashed, "host_00000.bin")
    with open(bin_path, "r+b") as f:
        f.truncate(os.path.getsize(bin_path) // 2)
    # LATEST still points at 9 — the completeness check must override it
    assert latest_step(d) == 5
    restored, _ = restore_checkpoint(d, jax.eval_shape(lambda: tree))
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))


def test_truncated_shard_with_commit_raises(tmp_path):
    """Truncation *behind* a COMMIT (disk fault, not a crash) is corruption:
    restore must raise, not silently zero-fill."""
    tree = {"w": jnp.arange(64, dtype=jnp.float32)}
    d = str(tmp_path / "c")
    path = save_checkpoint(d, 1, tree)
    bin_path = os.path.join(path, "host_00000.bin")
    with open(bin_path, "r+b") as f:
        f.truncate(os.path.getsize(bin_path) - 8)
    with pytest.raises(IOError, match="truncated"):
        restore_checkpoint(d, jax.eval_shape(lambda: tree))


def test_legacy_npz_readable_behind_version_switch(tmp_path):
    """v1 dirs (arrays.npz, no format_version, no COMMIT) restore through
    the same entry point, and count as complete for latest_step."""
    state, _ = _nonzero_state("adamw4bit")
    d = str(tmp_path / "c")
    save_checkpoint(d, 4, state, fmt_version="npz")
    assert not os.path.exists(os.path.join(d, "step_00000004", "COMMIT"))
    assert latest_step(d) == 4
    restored, _ = restore_checkpoint(d, jax.eval_shape(lambda: state))
    _assert_trees_bitwise(restored, state, "legacy npz roundtrip")


# ---------------------------------------------------------------------------
# sharded save/restore on the 8-device harness
# ---------------------------------------------------------------------------


def _sharded_state_on(mesh, state, axes, zero=True):
    shardings = train_state_shardings(state, axes, mesh, zero=zero)
    return jax.device_put(state, shardings), shardings


@needs_8_devices
def test_elastic_reshard_2x4_to_4x2_bitwise(tmp_path):
    """Save on (2,4), restore onto (4,2) AND onto a single device: every
    leaf — packed 4-bit codes, scales, fp32 params, the SR key — bit-exact."""
    state, axes = _nonzero_state()
    mesh1 = jax.make_mesh((2, 4), ("data", "model"))
    sharded, _ = _sharded_state_on(mesh1, state, axes)
    d = str(tmp_path / "c")
    save_checkpoint(d, 2, sharded)

    target = jax.eval_shape(lambda: state)
    mesh2 = jax.make_mesh((4, 2), ("data", "model"))
    shardings2 = train_state_shardings(target, axes, mesh2, zero=True)
    restored, _ = restore_checkpoint(d, target, shardings=shardings2)
    _assert_trees_bitwise(restored, state, "2x4 -> 4x2 reshard")
    # spot-check the restored layout actually lives on mesh2
    flat = [l for _, l in _flat_with_keys(restored)]
    assert any(
        isinstance(l, jax.Array) and not l.sharding.is_fully_replicated
        for l in flat
    ), "restore produced no sharded leaves — shardings were ignored"

    single, _ = restore_checkpoint(d, target)  # no shardings: default device
    _assert_trees_bitwise(single, state, "2x4 -> single device")
    # quantized moments survive as QuantizedTensor leaves with packed codes
    q = [l for _, l in _flat_with_keys(single)]
    assert any(np.asarray(x).dtype == np.uint8 for x in q), "no packed codes?"


@needs_8_devices
def test_gather_spy_save_never_materializes_global(tmp_path, monkeypatch):
    """Every device->host byte the writer moves goes through
    ``writer._device_to_host``; for leaves that are actually split across
    devices, no single copy may be global-sized."""
    state, axes = _nonzero_state()
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    sharded, _ = _sharded_state_on(mesh, state, axes)

    global_nbytes = {}   # leaf key -> global nbytes
    split = set()        # keys split into >1 distinct shard index
    for key, leaf in _flat_with_keys(sharded):
        if not isinstance(leaf, jax.Array):
            continue
        global_nbytes[key] = leaf.size * np.dtype(leaf.dtype).itemsize
        idx = {
            tuple(map(tuple, ckfmt.normalize_index(s.index, leaf.shape)))
            for s in leaf.addressable_shards
        }
        if len(idx) > 1:
            split.add(key)
    assert split, "harness bug: nothing is sharded, the spy would prove nothing"

    copies = []
    real = writer._device_to_host
    monkeypatch.setattr(
        writer, "_device_to_host",
        lambda key, data: copies.append((key, np.asarray(data).nbytes))
        or real(key, data),
    )
    d = str(tmp_path / "c")
    save_checkpoint(d, 1, sharded)
    assert copies, "spy never fired — writer bypassed the seam"
    for key, nbytes in copies:
        if key in split:
            assert nbytes < global_nbytes[key], (
                f"save materialized a full global copy of split leaf {key}"
            )


@needs_8_devices
def test_gather_spy_restore_assembles_regions_only(tmp_path, monkeypatch):
    """Restoring onto a sharded target allocates per-device regions, never a
    full global array, for every split target leaf — even when the on-disk
    layout (2x4) differs from the target (4x2)."""
    state, axes = _nonzero_state()
    mesh1 = jax.make_mesh((2, 4), ("data", "model"))
    sharded, _ = _sharded_state_on(mesh1, state, axes)
    d = str(tmp_path / "c")
    save_checkpoint(d, 1, sharded)

    target = jax.eval_shape(lambda: state)
    mesh2 = jax.make_mesh((4, 2), ("data", "model"))
    shardings2 = train_state_shardings(target, axes, mesh2, zero=True)
    sh_leaves = jax.tree_util.tree_leaves(
        shardings2, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
    )
    keys = [k for k, _ in _flat_with_keys(target)]
    split = {
        k
        for k, sh, (_, t) in zip(
            keys, sh_leaves, jax.tree_util.tree_flatten_with_path(target)[0]
        )
        if not sh.is_fully_replicated and int(np.prod(t.shape or (1,))) > 1
    }
    assert split, "harness bug: target has no split leaves"
    global_nbytes = {
        k: int(np.prod(t.shape, dtype=np.int64)) * np.dtype(t.dtype).itemsize
        for k, (_, t) in zip(keys, jax.tree_util.tree_flatten_with_path(target)[0])
    }

    regions = []
    real = reader._alloc_region
    monkeypatch.setattr(
        reader, "_alloc_region",
        lambda key, shape, dtype: regions.append(
            (key, int(np.prod(shape, dtype=np.int64)) * np.dtype(dtype).itemsize)
        )
        or real(key, shape, dtype),
    )
    restored, _ = restore_checkpoint(d, target, shardings=shardings2)
    assert regions, "spy never fired — reader bypassed the seam"
    for key, nbytes in regions:
        if key in split:
            assert nbytes < global_nbytes[key], (
                f"restore allocated a full global region for split leaf {key}"
            )
    _assert_trees_bitwise(restored, state, "spied restore is still bit-exact")


# ---------------------------------------------------------------------------
# async writer semantics
# ---------------------------------------------------------------------------


def test_async_save_returns_before_serialization(tmp_path, monkeypatch):
    """save() blocks only on the snapshot copy: it must return while the
    background serialization is still in flight; COMMIT lands at wait()."""
    gate = threading.Event()
    started = threading.Event()
    real = writer.write_snapshot

    def gated(directory, step, snap, extra=None):
        started.set()
        assert gate.wait(30), "test gate never opened"
        return real(directory, step, snap, extra)

    monkeypatch.setattr(writer, "write_snapshot", gated)
    tree = {"w": jnp.arange(4096, dtype=jnp.float32)}
    d = str(tmp_path / "c")
    mgr = CheckpointManager(d)
    mgr.save(1, tree)  # must NOT block on the gated serialization
    assert started.wait(30), "background writer never started"
    assert not os.path.exists(os.path.join(d, "step_00000001", "COMMIT"))

    # double buffering: a SECOND save may also proceed (one writing, one
    # queued); only a third would block.  Run it on a thread to bound time.
    second_done = threading.Event()
    t = threading.Thread(
        target=lambda: (mgr.save(2, tree), second_done.set()), daemon=True
    )
    t.start()
    assert second_done.wait(30), "second save blocked — writer is not double-buffered"

    gate.set()
    mgr.wait()
    assert os.path.exists(os.path.join(d, "step_00000002", "COMMIT"))
    assert latest_step(d) == 2


def test_async_writer_surfaces_errors(tmp_path, monkeypatch):
    def boom(directory, step, snap, extra=None):
        raise RuntimeError("disk on fire")

    monkeypatch.setattr(writer, "write_snapshot", boom)
    mgr = CheckpointManager(str(tmp_path / "c"))
    mgr.save(1, {"w": jnp.zeros(4)})
    with pytest.raises(RuntimeError, match="disk on fire"):
        mgr.wait()


def test_async_roundtrip_through_manager(tmp_path):
    state, _ = _nonzero_state("adamw4bit")
    mgr = CheckpointManager(str(tmp_path / "c"))
    mgr.save(3, state, extra={"k": 1})
    restored, extra = mgr.restore(jax.eval_shape(lambda: state))
    assert extra == {"k": 1}
    _assert_trees_bitwise(restored, state, "manager async roundtrip")


# ---------------------------------------------------------------------------
# retention / GC
# ---------------------------------------------------------------------------


def _steps_on_disk(d):
    return sorted(
        ckfmt.parse_step(n) for n in os.listdir(d) if n.startswith("step_")
    )


def test_retention_keep_last_and_keep_every(tmp_path):
    tree = {"w": jnp.arange(8, dtype=jnp.float32)}
    d = str(tmp_path / "c")
    mgr = CheckpointManager(d, keep_last=2, keep_every=4)
    for s in range(1, 9):
        mgr.save(s, tree, block=True)
    assert _steps_on_disk(d) == [4, 7, 8]  # keep_every: 4, 8; keep_last: 7, 8
    restored, _ = restore_checkpoint(d, jax.eval_shape(lambda: tree), step=4)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))


def test_gc_never_deletes_newest_complete(tmp_path):
    tree = {"w": jnp.arange(8, dtype=jnp.float32)}
    d = str(tmp_path / "c")
    mgr = CheckpointManager(d, keep_last=1)
    mgr.save(1, tree, block=True)
    assert _steps_on_disk(d) == [1]
    mgr.save(2, tree, block=True)
    assert _steps_on_disk(d) == [2]


def test_resave_keeps_durable_copy_until_commit(tmp_path, monkeypatch):
    """Re-saving an already-committed step (replay after a forced rewind)
    must not destroy the durable copy before the replacement commits: the
    new attempt serializes into a staging dir and only swaps in at the end,
    so a kill mid-serialization leaves the original step fully intact."""
    tree = {"w": jnp.arange(8, dtype=jnp.float32)}
    d = str(tmp_path / "c")
    path = save_checkpoint(d, 1, tree)

    real = writer._barrier

    def dying_barrier(name):
        if name.startswith("ckpt_written"):
            raise RuntimeError("killed between shard write and COMMIT")
        return real(name)

    monkeypatch.setattr(writer, "_barrier", dying_barrier)
    with pytest.raises(RuntimeError, match="killed"):
        save_checkpoint(d, 1, {"w": jnp.arange(8, dtype=jnp.float32) * 2})
    # the original committed step was never touched — only an orphaned
    # staging dir remains, invisible to step discovery
    assert ckfmt.is_complete(path)
    assert latest_step(d) == 1
    assert any(".attempt_" in n for n in os.listdir(d))
    restored, _ = restore_checkpoint(d, jax.eval_shape(lambda: tree))
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))

    monkeypatch.setattr(writer, "_barrier", real)
    new_tree = {"w": jnp.arange(8, dtype=jnp.float32) * 2}
    save_checkpoint(d, 1, new_tree)  # retry succeeds and replaces
    assert ckfmt.is_complete(path)
    assert not os.path.exists(path + ".replaced"), "backup not cleaned up"
    restored, _ = restore_checkpoint(d, jax.eval_shape(lambda: tree))
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(new_tree["w"]))


def test_repair_restores_set_aside_copy(tmp_path):
    """The one vulnerable instant of the swap is between rename(final ->
    .replaced) and rename(stage -> final); a kill there leaves only the
    .replaced durable copy, which latest_step repairs back into place."""
    tree = {"w": jnp.arange(8, dtype=jnp.float32)}
    d = str(tmp_path / "c")
    path = save_checkpoint(d, 1, tree)
    os.rename(path, path + ".replaced")  # simulate the mid-swap kill
    assert latest_step(d) == 1
    assert ckfmt.is_complete(path) and not os.path.exists(path + ".replaced")
    restored, _ = restore_checkpoint(d, jax.eval_shape(lambda: tree))
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))


def test_gc_drops_abandoned_timeline_after_rewind(tmp_path):
    """After a forced rewind, committing an older step collects the stale
    future steps of the abandoned timeline (they would otherwise pin
    keep_last slots and confuse a fallback latest_step scan forever)."""
    tree = {"w": jnp.arange(8, dtype=jnp.float32)}
    d = str(tmp_path / "c")
    mgr = CheckpointManager(d, keep_last=3)
    for s in (10, 20, 30):
        mgr.save(s, tree, block=True)
    mgr.save(15, tree, block=True)  # rewound to 10, replayed to 15
    assert _steps_on_disk(d) == [10, 15], "stale future steps not collected"
    assert latest_step(d) == 15


def test_restore_target_with_plain_scalar_leaf(tmp_path):
    """Concrete targets may carry plain Python scalars (no .shape); the v2
    reader must restore around them instead of raising AttributeError."""
    tree = {"w": jnp.arange(4, dtype=jnp.float32), "n": 3}
    d = str(tmp_path / "c")
    save_checkpoint(d, 1, tree)
    restored, _ = restore_checkpoint(d, tree)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
    assert np.asarray(restored["n"]).item() == 3


def test_gc_sweeps_crash_leftovers(tmp_path):
    tree = {"w": jnp.arange(8, dtype=jnp.float32)}
    d = str(tmp_path / "c")
    mgr = CheckpointManager(d, keep_last=3)
    mgr.save(1, tree, block=True)
    crashed = save_checkpoint(d, 2, tree)
    os.remove(os.path.join(crashed, "COMMIT"))  # simulated kill
    mgr.save(3, tree, block=True)  # commit + GC
    assert 2 not in _steps_on_disk(d), "incomplete crash leftover not swept"
    assert _steps_on_disk(d) == [1, 3]
