"""End-to-end checkpoint round-trips for every registered optimizer.

The enforced invariant: ``make_train_state -> 3 steps -> save -> restore ->
3 more steps`` is BIT-IDENTICAL to 6 uninterrupted steps — params, step
counters, the SR key, and every compressed state leaf (packed 4-bit codes and
their scales).  Under stochastic rounding this additionally proves the SR key
stream is a pure function of (base key, step): the restored run re-derives
the identical quantization noise.

Also covers: multi-device mesh resume (fresh mesh instance + explicit
shardings), elastic restore onto a different mesh layout, the manifest
structure guard, and legacy dict-state migration.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from legacy_optimizers import legacy_quantized_adamw
from repro.core.optimizers import (
    QuantPolicy,
    adamw4bit,
    adamw8bit,
    make_optimizer,
    optimizer_names,
    sgdm4bit,
)
from repro.core.optimizers.adamw import M_4BIT, V_4BIT
from repro.core.optimizers.transform import ChainState
from repro.core.quantizer import QuantizedTensor
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import LayerSpec, ModelConfig, init_model
from repro.train.checkpoint import (
    migrate_legacy_state,
    restore_checkpoint,
    save_checkpoint,
)
from repro.train.train_loop import (
    build_train_step,
    jit_train_step,
    make_train_state,
    train_state_shardings,
)

jax.config.update("jax_platform_name", "cpu")

# mesh tests need the multi-device harness (conftest forces 8 CPU devices by
# default; the CI checkpoint matrix also runs with REPRO_FORCE_DEVICES=1)
needs_8_devices = pytest.mark.skipif(
    len(jax.devices()) < 8, reason="needs the 8-device host harness"
)

MICRO_CFG = ModelConfig(
    name="micro-lm",
    num_layers=1,
    d_model=64,
    num_heads=2,
    num_kv_heads=1,
    head_dim=32,
    d_ff=128,
    vocab_size=256,  # embed = 256*64 = 16384 elements > threshold -> quantized
    blocks=(LayerSpec("dense", 0),),
    remat=False,
)

_DATA = SyntheticLM(DataConfig(MICRO_CFG.vocab_size, 16, 8, seed=2))


def _batch(t):
    return {k: jnp.asarray(v) for k, v in _DATA.batch_at(t).items()}


def _assert_states_bitwise(a, b, what=""):
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb, f"{what}: tree structure mismatch"
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y), err_msg=what)


CASES = [(name, {}) for name in optimizer_names()]
CASES.append(("adamw4bit", {"stochastic_rounding": True}))
CASE_IDS = [n for n, _ in CASES[:-1]] + ["adamw4bit_sr"]


@pytest.mark.parametrize("name,overrides", CASES, ids=CASE_IDS)
def test_roundtrip_bit_identical_all_optimizers(name, overrides, tmp_path):
    opt = make_optimizer(name, 3e-3, **overrides)
    params, _ = init_model(jax.random.PRNGKey(0), MICRO_CFG)
    key = jax.random.PRNGKey(5)  # harmless for RTN optimizers, load-bearing for SR
    state = make_train_state(params, opt, key=key)
    step_fn = jax.jit(build_train_step(MICRO_CFG, opt))

    for t in range(3):
        state, _ = step_fn(state, _batch(t))
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 3, state)

    uninterrupted = state
    for t in range(3, 6):
        uninterrupted, _ = step_fn(uninterrupted, _batch(t))

    # restore on a "fresh process": abstract target, no concrete reuse
    target = jax.eval_shape(lambda: make_train_state(params, opt, key=key))
    restored, _ = restore_checkpoint(d, target)
    _assert_states_bitwise(restored, state, f"{name}: restored state @3")
    for t in range(3, 6):
        restored, _ = step_fn(restored, _batch(t))
    _assert_states_bitwise(
        restored, uninterrupted, f"{name}: resumed vs uninterrupted @6"
    )


# d_ff=256 -> the mlp w1/w3 leaves (1, 64, 256) are fused-kernel eligible, so
# these runs exercise the in-kernel SR requantization path end to end.
KERNEL_CFG = ModelConfig(
    name="micro-kernel-lm",
    num_layers=1,
    d_model=64,
    num_heads=2,
    num_kv_heads=1,
    head_dim=32,
    d_ff=256,
    vocab_size=256,
    blocks=(LayerSpec("dense", 0),),
    remat=False,
)


@pytest.mark.parametrize("backend", ["ref", "interpret"])
@pytest.mark.parametrize(
    "name,overrides",
    [
        ("production4bit", {}),
        ("adamw4bit", {"stochastic_rounding": True, "use_kernel": True}),
    ],
    ids=["production4bit", "adamw4bit_sr_kernel"],
)
def test_roundtrip_bit_identical_fused_sr_path(
    name, overrides, backend, tmp_path, monkeypatch
):
    """save -> restore -> continue through the *fused SR* route is bit-exact:
    the per-step SR key stream is a pure function of (base key, step), the
    in-kernel Threefry noise a pure function of (leaf key, element), so the
    restored run re-derives identical codes — on both the pure-jnp reference
    backend and the Pallas kernel in interpret mode."""
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", backend)
    opt = make_optimizer(name, 3e-3, **overrides)
    params, _ = init_model(jax.random.PRNGKey(0), KERNEL_CFG)
    key = jax.random.PRNGKey(17)
    state = make_train_state(params, opt, key=key)
    step_fn = jax.jit(build_train_step(KERNEL_CFG, opt))

    for t in range(3):
        state, _ = step_fn(state, _batch(t))
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 3, state)

    uninterrupted = state
    for t in range(3, 6):
        uninterrupted, _ = step_fn(uninterrupted, _batch(t))

    target = jax.eval_shape(lambda: make_train_state(params, opt, key=key))
    restored, _ = restore_checkpoint(d, target)
    _assert_states_bitwise(restored, state, f"{name}/{backend}: restored @3")
    for t in range(3, 6):
        restored, _ = step_fn(restored, _batch(t))
    _assert_states_bitwise(
        restored, uninterrupted, f"{name}/{backend}: fused-SR resume vs uninterrupted"
    )
    # sanity: the fused route actually owns leaves in this config — the mlp
    # moments are quantized with SR and kernel-eligible
    from repro.core.optimizers.transform import FusedAdamWRoute

    opt_state = restored.opt_state
    chain_state = opt_state.states["4bit"] if name == "production4bit" else opt_state
    m_leaf = chain_state["m"]["decoder"][0]["sub0"]["mlp"]["w1"]
    v_leaf = chain_state["v"]["decoder"][0]["sub0"]["mlp"]["w1"]
    assert isinstance(m_leaf, QuantizedTensor) and m_leaf.config.stochastic_rounding
    p_leaf = restored.params["decoder"][0]["sub0"]["mlp"]["w1"]
    assert FusedAdamWRoute(lr=3e-3).eligible({"m": m_leaf, "v": v_leaf}, p_leaf)


def _mesh_step(opt, mesh, axes, state):
    train_step = build_train_step(MICRO_CFG, opt, mesh, axes, zero=True)
    return jit_train_step(train_step, state, _batch(0), axes, mesh, donate=False)


@pytest.mark.slow
@needs_8_devices
@pytest.mark.parametrize(
    "name,overrides",
    [("adamw4bit", {"stochastic_rounding": True}), ("production4bit", {})],
    ids=["adamw4bit_sr", "production4bit"],
)
def test_mesh_resume_bit_exact(name, overrides, tmp_path):
    """SR training under pjit on a 2x4 host mesh: save -> restore onto a
    FRESH mesh (new Mesh object, new jit) with explicit shardings -> continue
    == uninterrupted, bit-exactly."""
    opt = make_optimizer(name, 3e-3, **overrides)
    params, axes = init_model(jax.random.PRNGKey(0), MICRO_CFG)
    key = jax.random.PRNGKey(11)

    mesh1 = jax.make_mesh((2, 4), ("data", "model"))
    state = make_train_state(params, opt, key=key)
    step1 = _mesh_step(opt, mesh1, axes, state)
    for t in range(3):
        state, metrics = step1(state, _batch(t))
    assert np.isfinite(float(metrics["loss"]))
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 3, state)
    uninterrupted = state
    for t in range(3, 6):
        uninterrupted, _ = step1(uninterrupted, _batch(t))

    # fresh mesh + fresh jit, restore with explicit shardings
    mesh2 = jax.make_mesh((2, 4), ("data", "model"))
    target = jax.eval_shape(lambda: make_train_state(params, opt, key=key))
    shardings = train_state_shardings(target, axes, mesh2, zero=True)
    restored, _ = restore_checkpoint(d, target, shardings=shardings)
    step2 = _mesh_step(opt, mesh2, axes, restored)
    for t in range(3, 6):
        restored, _ = step2(restored, _batch(t))
    _assert_states_bitwise(restored, uninterrupted, f"{name}: mesh resume @6")


@pytest.mark.slow
@needs_8_devices
def test_elastic_restore_different_mesh_layout(tmp_path):
    """A checkpoint saved on (2,4) restores and trains on (4,2) — elastic
    restart across layouts (numerics may differ in reduction order, so this
    asserts close, not bitwise)."""
    opt = make_optimizer("production4bit", 3e-3)
    params, axes = init_model(jax.random.PRNGKey(0), MICRO_CFG)
    key = jax.random.PRNGKey(11)
    mesh1 = jax.make_mesh((2, 4), ("data", "model"))
    state = make_train_state(params, opt, key=key)
    step1 = _mesh_step(opt, mesh1, axes, state)
    for t in range(2):
        state, _ = step1(state, _batch(t))
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 2, state)
    ref, _ = step1(state, _batch(2))

    mesh2 = jax.make_mesh((4, 2), ("data", "model"))
    target = jax.eval_shape(lambda: make_train_state(params, opt, key=key))
    shardings = train_state_shardings(target, axes, mesh2, zero=True)
    restored, _ = restore_checkpoint(d, target, shardings=shardings)
    step2 = _mesh_step(opt, mesh2, axes, restored)
    cont, metrics = step2(restored, _batch(2))
    assert np.isfinite(float(metrics["loss"]))
    for a, b in zip(
        jax.tree_util.tree_leaves(ref.params), jax.tree_util.tree_leaves(cont.params)
    ):
        # Different layout => different reduction order.  Near a 4-bit code
        # boundary that can flip a single quantized-state element by one bin,
        # so bound the outlier fraction and magnitude instead of demanding
        # uniform closeness.
        diff = np.abs(np.asarray(a) - np.asarray(b))
        assert float(np.mean(diff > 5e-4)) < 1e-3, float(np.mean(diff > 5e-4))
        assert float(diff.max()) < 5e-3, float(diff.max())


def test_restore_rejects_structure_mismatch(tmp_path):
    """The manifest records the transform-chain structure; restoring into a
    different optimizer's state fails loudly, not by leaf misassignment."""
    params, _ = init_model(jax.random.PRNGKey(0), MICRO_CFG)
    state = make_train_state(params, make_optimizer("adamw4bit", 1e-3))
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, state)
    wrong = jax.eval_shape(
        lambda: make_train_state(params, make_optimizer("adamw32", 1e-3))
    )
    with pytest.raises(ValueError, match="structure mismatch"):
        restore_checkpoint(d, wrong)


# ---------------------------------------------------------------------------
# legacy dict-state migration
# ---------------------------------------------------------------------------


def _legacy_params():
    rng = np.random.default_rng(3)
    f32 = lambda a: jnp.asarray(a.astype(np.float32))
    return {
        "embed": f32(rng.normal(size=(64, 256)) * 0.1),
        "w": f32(rng.normal(size=(16, 512)) * 0.1),
        "bias": f32(rng.normal(size=(64,)) * 0.1),
    }


def _legacy_grads(t, params):
    rng = np.random.default_rng(100 + t)
    return jax.tree_util.tree_map(
        lambda p: jnp.asarray(rng.normal(size=p.shape).astype(np.float32) * 0.02),
        params,
    )


def test_migrate_legacy_adamw4bit_state_continues_bit_identical():
    """legacy run -> migrate_legacy_state -> chain run continues exactly as
    the legacy optimizer would have (the chain is bit-identical to the legacy
    oracle, so migration must hand it an equivalent state)."""
    params = _legacy_params()
    legacy = legacy_quantized_adamw(
        3e-3,
        m_policy=QuantPolicy(config=M_4BIT),
        v_policy=QuantPolicy(config=V_4BIT),
    )
    p_l, s_l = params, legacy.init(params)
    for t in range(3):
        p_l, s_l = legacy.update(_legacy_grads(t, params), s_l, p_l)

    new_opt = adamw4bit(3e-3)
    migrated = migrate_legacy_state(s_l, new_opt)
    assert isinstance(migrated, ChainState)
    assert isinstance(migrated["m"]["w"], QuantizedTensor)
    assert int(np.asarray(migrated[0].count)) == 3

    p_new, s_new = p_l, migrated
    for t in range(3, 6):
        g = _legacy_grads(t, params)
        p_l, s_l = legacy.update(g, s_l, p_l)
        p_new, s_new = new_opt.update(g, s_new, p_new)
    _assert_states_bitwise(p_new, p_l, "migrated chain vs legacy params")
    _assert_states_bitwise(s_new["m"], s_l["m"], "migrated m")
    _assert_states_bitwise(s_new["v"], s_l["v"], "migrated v")


def test_migrate_legacy_state_validates_policies():
    """Migrating a 4-bit legacy state into an 8-bit chain must fail loudly
    (the quantizer configs are part of the state structure)."""
    params = _legacy_params()
    legacy = legacy_quantized_adamw(
        1e-3,
        m_policy=QuantPolicy(config=M_4BIT),
        v_policy=QuantPolicy(config=V_4BIT),
    )
    s_l = legacy.init(params)
    with pytest.raises(ValueError, match="quantization policies"):
        migrate_legacy_state(s_l, adamw8bit(1e-3))


def test_migrate_legacy_sgdm_renames_m_to_trace():
    from legacy_optimizers import legacy_sgdm4bit

    params = _legacy_params()
    legacy = legacy_sgdm4bit(5e-3)
    key = jax.random.PRNGKey(9)
    p_l, s_l = params, legacy.init(params)
    for t in range(2):
        p_l, s_l = legacy.update(
            _legacy_grads(t, params), s_l, p_l, key=jax.random.fold_in(key, t)
        )
    new_opt = sgdm4bit(5e-3)
    migrated = migrate_legacy_state(s_l, new_opt)
    _assert_states_bitwise(migrated["trace"], s_l["m"], "sgdm trace")
    p_new, s_new = p_l, migrated
    for t in range(2, 4):
        g = _legacy_grads(t, params)
        k = jax.random.fold_in(key, t)
        p_l, s_l = legacy.update(g, s_l, p_l, key=k)
        p_new, s_new = new_opt.update(g, s_new, p_new, key=k)
    _assert_states_bitwise(p_new, p_l, "migrated sgdm params")
