"""Pre-refactor optimizer implementations, kept verbatim as test oracles.

These are the monolithic per-leaf flatten loops that the transform API
(chain/compressed/partition) replaced.  tests/test_transforms.py asserts the
chain rebuilds are BIT-IDENTICAL to these over multi-step trajectories —
params and every compressed/factored/raw state leaf.  Do not "improve" this
file; its value is that it does not change.
"""

from __future__ import annotations

from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp

from repro.core.optimizers.base import (
    FactoredMoment,
    Optimizer,
    QuantPolicy,
    compress_moment,
    decompress_moment,
    tree_paths,
)
from repro.core.quantizer import QuantConfig, QuantizedTensor

Schedule = Union[float, Callable[[jnp.ndarray], jnp.ndarray]]

M_4BIT = QuantConfig(bits=4, normalization="blockwise", block_size=128, mapping="de", signed=True)
V_4BIT = QuantConfig(bits=4, normalization="rank1", mapping="linear", signed=False)
M_8BIT = QuantConfig(bits=8, normalization="blockwise", block_size=2048, mapping="de", signed=True)
V_8BIT = QuantConfig(bits=8, normalization="blockwise", block_size=2048, mapping="de", signed=False)


def _resolve_lr(lr: Schedule, step: jnp.ndarray) -> jnp.ndarray:
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


def legacy_quantized_adamw(
    lr: Schedule,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
    m_policy: Optional[QuantPolicy] = None,
    v_policy: Optional[QuantPolicy] = None,
    use_kernel: bool = False,
    name: str = "adamw",
) -> Optimizer:
    m_policy = m_policy or QuantPolicy()
    v_policy = v_policy or QuantPolicy()

    def init(params):
        paths = tree_paths(params)

        def init_m(path, p):
            mode = m_policy.mode(path, p.shape)
            zero = jnp.zeros(p.shape, jnp.float32)
            return compress_moment(zero, mode, m_policy.config)

        def init_v(path, p):
            mode = v_policy.mode(path, p.shape)
            if mode == "factor":
                return FactoredMoment.zeros(p.shape)
            zero = jnp.zeros(p.shape, jnp.float32)
            return compress_moment(zero, mode, v_policy.config)

        return {
            "m": jax.tree_util.tree_map(init_m, paths, params),
            "v": jax.tree_util.tree_map(init_v, paths, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, key: Optional[jax.Array] = None):
        step = state["step"] + 1
        lr_t = _resolve_lr(lr, step)
        bc1 = 1.0 - jnp.power(jnp.float32(b1), step.astype(jnp.float32))
        bc2 = 1.0 - jnp.power(jnp.float32(b2), step.astype(jnp.float32))

        leaves_g, treedef = jax.tree_util.tree_flatten(grads)
        leaves_p = treedef.flatten_up_to(params)
        is_state_leaf = lambda x: isinstance(x, (QuantizedTensor, FactoredMoment))
        leaves_m = jax.tree_util.tree_flatten(state["m"], is_leaf=is_state_leaf)[0]
        leaves_v = jax.tree_util.tree_flatten(state["v"], is_leaf=is_state_leaf)[0]

        new_p, new_m, new_v = [], [], []
        for i, (g, p, m_s, v_s) in enumerate(
            zip(leaves_g, leaves_p, leaves_m, leaves_v)
        ):
            leaf_key = None
            if key is not None:
                leaf_key = jax.random.fold_in(key, i)
            if use_kernel and _kernel_eligible(m_s, v_s, p):
                from repro.kernels import ops as kernel_ops

                p2, m2, v2 = kernel_ops.fused_adamw4_leaf(
                    p, g, m_s, v_s, lr_t, b1, b2, eps, weight_decay, bc1, bc2
                )
            else:
                p2, m2, v2 = _reference_leaf_update(
                    p, g, m_s, v_s, lr_t, b1, b2, eps, weight_decay, bc1, bc2,
                    leaf_key,
                )
            new_p.append(p2)
            new_m.append(m2)
            new_v.append(v2)

        return (
            jax.tree_util.tree_unflatten(treedef, new_p),
            {
                "m": jax.tree_util.tree_unflatten(treedef, new_m),
                "v": jax.tree_util.tree_unflatten(treedef, new_v),
                "step": step,
            },
        )

    return Optimizer(init=init, update=update, name=name)


def _kernel_eligible(m_s, v_s, p) -> bool:
    return (
        isinstance(m_s, QuantizedTensor)
        and m_s.config.bits == 4
        and m_s.config.normalization == "blockwise"
        and m_s.config.block_size == 128
        and not m_s.config.stochastic_rounding
        and isinstance(v_s, QuantizedTensor)
        and v_s.config.bits == 4
        and v_s.config.normalization == "rank1"
        and not v_s.config.stochastic_rounding
        and p.ndim == 2
        and p.shape[-1] % 256 == 0  # nibble + B128 tile alignment
    )


def _reference_leaf_update(
    p, g, m_s, v_s, lr_t, b1, b2, eps, weight_decay, bc1, bc2, key
):
    g = g.astype(jnp.float32)
    m = decompress_moment(m_s)
    m = b1 * m + (1.0 - b1) * g

    if isinstance(v_s, FactoredMoment):
        v_fac = v_s.ema_update(g * g, b2)
        v = v_fac.reconstruct()
        new_v = v_fac
    else:
        v = decompress_moment(v_s)
        v = b2 * v + (1.0 - b2) * g * g
        new_v = None  # compressed below

    m_hat = m / bc1
    v_hat = v / bc2
    update = m_hat / (jnp.sqrt(v_hat) + eps)
    p2 = (p.astype(jnp.float32) - lr_t * (update + weight_decay * p)).astype(p.dtype)

    m_key = v_key = None
    if key is not None:
        m_key, v_key = jax.random.split(key)
    if isinstance(m_s, QuantizedTensor):
        m2 = compress_moment(m, "quant", m_s.config, key=m_key)
    else:
        m2 = m
    if new_v is None:
        if isinstance(v_s, QuantizedTensor):
            new_v = compress_moment(v, "quant", v_s.config, key=v_key)
        else:
            new_v = v
    return p2, m2, new_v


def legacy_sgdm(
    lr: Schedule,
    beta: float = 0.9,
    weight_decay: float = 0.0,
    m_policy: Optional[QuantPolicy] = None,
    name: str = "sgdm",
) -> Optimizer:
    m_policy = m_policy or QuantPolicy()

    def init(params):
        paths = tree_paths(params)

        def init_m(path, p):
            mode = m_policy.mode(path, p.shape)
            return compress_moment(
                jnp.zeros(p.shape, jnp.float32), mode, m_policy.config
            )

        return {
            "m": jax.tree_util.tree_map(init_m, paths, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, key=None):
        step = state["step"] + 1
        lr_t = lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)

        is_leaf = lambda x: isinstance(x, QuantizedTensor)
        leaves_g, treedef = jax.tree_util.tree_flatten(grads)
        leaves_p = treedef.flatten_up_to(params)
        leaves_m = jax.tree_util.tree_flatten(state["m"], is_leaf=is_leaf)[0]

        new_p, new_m = [], []
        for i, (g, p, m_s) in enumerate(zip(leaves_g, leaves_p, leaves_m)):
            g = g.astype(jnp.float32)
            m = decompress_moment(m_s)
            m = beta * m + g
            p2 = (
                p.astype(jnp.float32) - lr_t * (m + weight_decay * p)
            ).astype(p.dtype)
            if isinstance(m_s, QuantizedTensor):
                leaf_key = (
                    jax.random.fold_in(key, i) if key is not None else None
                )
                m2 = compress_moment(m, "quant", m_s.config, key=leaf_key)
            else:
                m2 = m
            new_p.append(p2)
            new_m.append(m2)

        return (
            jax.tree_util.tree_unflatten(treedef, new_p),
            {"m": jax.tree_util.tree_unflatten(treedef, new_m), "step": step},
        )

    return Optimizer(init=init, update=update, name=name)


def legacy_sgdm4bit(lr: Schedule, beta: float = 0.9, stochastic_rounding: bool = True, **kw) -> Optimizer:
    cfg = QuantConfig(
        bits=4,
        normalization="blockwise",
        block_size=128,
        mapping="de",
        signed=True,
        stochastic_rounding=stochastic_rounding,
    )
    return legacy_sgdm(lr, beta=beta, m_policy=QuantPolicy(config=cfg), name="sgdm4bit", **kw)


def _broadcast_min(accs, shape):
    out = None
    for r, acc in enumerate(accs):
        view = [1] * len(shape)
        view[r] = shape[r]
        b = acc.reshape(view)
        out = b if out is None else jnp.minimum(out, b)
    return jnp.broadcast_to(out, shape)


def legacy_sm3(
    lr: Schedule,
    b1: float = 0.9,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
) -> Optimizer:
    def init(params):
        def init_acc(p):
            if p.ndim == 0:
                return (jnp.zeros((1,), jnp.float32),)
            return tuple(jnp.zeros((d,), jnp.float32) for d in p.shape)

        return {
            "acc": jax.tree_util.tree_map(
                init_acc, params, is_leaf=lambda x: hasattr(x, "shape")
            ),
            "m": jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            ),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, key=None):
        del key
        step = state["step"] + 1
        lr_t = lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)

        leaves_g, treedef = jax.tree_util.tree_flatten(grads)
        leaves_p = treedef.flatten_up_to(params)
        leaves_acc = treedef.flatten_up_to(state["acc"])
        leaves_m = treedef.flatten_up_to(state["m"])

        new_p, new_acc, new_m = [], [], []
        for g, p, accs, m in zip(leaves_g, leaves_p, leaves_acc, leaves_m):
            g = g.astype(jnp.float32)
            shape = g.shape if g.ndim > 0 else (1,)
            g_ = g.reshape(shape)
            nu = _broadcast_min(accs, shape) + g_ * g_
            accs2 = tuple(
                jnp.max(nu, axis=tuple(i for i in range(len(shape)) if i != r))
                for r in range(len(shape))
            )
            u = (g_ / (jnp.sqrt(nu) + eps)).reshape(g.shape)
            m2 = b1 * m + (1 - b1) * u
            p2 = (p.astype(jnp.float32) - lr_t * (m2 + weight_decay * p)).astype(
                p.dtype
            )
            new_p.append(p2)
            new_acc.append(accs2)
            new_m.append(m2)

        return (
            jax.tree_util.tree_unflatten(treedef, new_p),
            {
                "acc": jax.tree_util.tree_unflatten(treedef, new_acc),
                "m": jax.tree_util.tree_unflatten(treedef, new_m),
                "step": step,
            },
        )

    return Optimizer(init=init, update=update, name="sm3")


def legacy_adafactor(
    lr: Schedule,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.01,
) -> Optimizer:
    def init(params):
        def init_v(p):
            if p.ndim >= 2:
                return FactoredMoment.zeros(p.shape)
            return jnp.zeros(p.shape, jnp.float32)

        state = {
            "v": jax.tree_util.tree_map(init_v, params),
            "step": jnp.zeros((), jnp.int32),
        }
        if b1 > 0:
            state["m"] = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
        return state

    def update(grads, state, params, key=None):
        del key
        step = state["step"] + 1
        lr_t = lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)
        bc2 = 1.0 - jnp.power(jnp.float32(b2), step.astype(jnp.float32))

        is_leaf = lambda x: isinstance(x, FactoredMoment)
        leaves_g, treedef = jax.tree_util.tree_flatten(grads)
        leaves_p = treedef.flatten_up_to(params)
        leaves_v = jax.tree_util.tree_flatten(state["v"], is_leaf=is_leaf)[0]
        leaves_m = (
            jax.tree_util.tree_flatten(state["m"])[0]
            if b1 > 0
            else [None] * len(leaves_g)
        )

        new_p, new_v, new_m = [], [], []
        for g, p, v_s, m in zip(leaves_g, leaves_p, leaves_v, leaves_m):
            g = g.astype(jnp.float32)
            sq = g * g + eps
            if isinstance(v_s, FactoredMoment):
                v2 = v_s.ema_update(sq, b2)
                v_hat = v2.reconstruct() / bc2
            else:
                v2 = b2 * v_s + (1 - b2) * sq
                v_hat = v2 / bc2
            u = g / jnp.sqrt(jnp.maximum(v_hat, eps))
            rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            if m is not None:
                m2 = b1 * m + (1 - b1) * u
                new_m.append(m2)
                u = m2
            p2 = (p.astype(jnp.float32) - lr_t * (u + weight_decay * p)).astype(
                p.dtype
            )
            new_p.append(p2)
            new_v.append(v2)

        out_state = {
            "v": jax.tree_util.tree_unflatten(treedef, new_v),
            "step": step,
        }
        if b1 > 0:
            out_state["m"] = jax.tree_util.tree_unflatten(treedef, new_m)
        return jax.tree_util.tree_unflatten(treedef, new_p), out_state

    return Optimizer(init=init, update=update, name=f"adafactor(b1={b1})")
