"""Crash-torture: a save killed mid-shard-write must be invisible to
recovery.

Scenario: the train loop checkpoints every 10 steps through the sharded
async manager; the step-20 save is "killed" mid-write (COMMIT marker
removed, shard file truncated — exactly what a SIGKILL between shard fsync
and commit leaves behind); a node failure is injected a few steps later.
``run_with_recovery`` + ``checkpoint_hooks`` must fall back to the last
COMMIT-complete step (10), replay from there, and converge to the same
final state as an uninterrupted run.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.io import CheckpointManager
from repro.io import format as ckfmt
from repro.train.fault_tolerance import checkpoint_hooks, run_with_recovery

jax.config.update("jax_platform_name", "cpu")


def _corrupt_midwrite(directory, step):
    """Make step's dir look like a save killed between shard write and
    COMMIT: marker gone, shard file cut short."""
    d = ckfmt.step_dir(directory, step)
    os.remove(os.path.join(d, ckfmt.COMMIT))
    bin_path = os.path.join(d, ckfmt.shard_file(0))
    with open(bin_path, "r+b") as f:
        f.truncate(os.path.getsize(bin_path) // 2)


def test_recovery_falls_back_past_uncommitted_save(tmp_path):
    steps = 30
    d = str(tmp_path / "ckpt")
    mgr = CheckpointManager(d, keep_last=5)

    holder = {"state": {"w": jnp.zeros((4, 4)), "count": jnp.asarray(0, jnp.int32)}}

    def train_one(step):
        s = holder["state"]
        holder["state"] = {"w": s["w"] + 1.0, "count": s["count"] + 1}
        return float(step)

    save, restore_latest = checkpoint_hooks(
        mgr,
        get_state=lambda: holder["state"],
        set_state=lambda s: holder.__setitem__("state", s),
        make_target=lambda: jax.eval_shape(lambda: holder["state"]),
    )

    failed = {"done": False}

    def injector(step):
        if step == 23 and not failed["done"]:
            failed["done"] = True
            # the step-20 save "crashed" mid-shard-write before the node died
            mgr.wait()
            assert mgr.latest_step() == 20
            _corrupt_midwrite(d, 20)
            assert mgr.latest_step() == 10, "completeness check missed the kill"
            return True
        return False

    losses, restarts, replayed = run_with_recovery(
        steps, train_one, save, restore_latest,
        checkpoint_every=10, failure_injector=injector,
    )
    assert restarts == 1
    assert replayed == 23 - 10, "recovery did not fall back to the last COMMIT"
    assert len(losses) == steps + replayed  # replayed steps re-train
    # the replayed run converges to the exact uninterrupted final state
    assert int(holder["state"]["count"]) == steps
    np.testing.assert_array_equal(
        np.asarray(holder["state"]["w"]), np.full((4, 4), float(steps))
    )
    # and the re-save of step 20 after recovery replaced the corpse
    mgr.wait()
    assert ckfmt.is_complete(ckfmt.step_dir(d, 20))


def test_recovery_survives_failed_async_save(tmp_path, monkeypatch):
    """A background save that errored (disk fault) must not abort recovery:
    restore_latest discards the pending error (with a warning) and falls
    back to the last COMMIT-complete step."""
    import pytest

    from repro.io import writer as ckwriter

    d = str(tmp_path / "ckpt")
    mgr = CheckpointManager(d)
    holder = {"state": {"w": jnp.zeros(2)}}
    mgr.save(5, holder["state"], block=True)  # durable step 5

    real = ckwriter.write_snapshot

    def boom(directory, step, snap, extra=None):
        raise OSError("no space left on device")

    monkeypatch.setattr(ckwriter, "write_snapshot", boom)
    mgr.save(7, holder["state"])  # fails in the background
    mgr._writer._queue.join()  # error now pending
    monkeypatch.setattr(ckwriter, "write_snapshot", real)

    _, restore_latest = checkpoint_hooks(
        mgr,
        get_state=lambda: holder["state"],
        set_state=lambda s: holder.__setitem__("state", s),
        make_target=lambda: jax.eval_shape(lambda: holder["state"]),
    )
    with pytest.warns(UserWarning, match="discarding failed async"):
        assert restore_latest() == 5


def test_recovery_with_no_checkpoint_restarts_from_zero(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ckpt"))
    holder = {"state": {"w": jnp.zeros(2)}}

    def train_one(step):
        holder["state"] = {"w": holder["state"]["w"] + 1.0}
        return 0.0

    save, restore_latest = checkpoint_hooks(
        mgr,
        get_state=lambda: holder["state"],
        set_state=lambda s: holder.__setitem__("state", s),
        make_target=lambda: jax.eval_shape(lambda: holder["state"]),
    )
    fail_once = {"done": False}

    def injector(step):
        if step == 3 and not fail_once["done"]:
            fail_once["done"] = True
            holder["state"] = {"w": jnp.zeros(2)}  # the "node" lost its state
            return True
        return False

    losses, restarts, replayed = run_with_recovery(
        8, train_one, save, restore_latest,
        checkpoint_every=100, failure_injector=injector,  # no save ever lands
    )
    assert restarts == 1 and replayed == 3
    assert float(holder["state"]["w"][0]) == 8.0
