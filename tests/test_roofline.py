"""Roofline machinery tests: collective parsing, scan undercount evidence,
cross-validation of the decomposed-compile methodology, sharding rules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import (
    _ring_bytes,
    collective_bytes_from_hlo,
    cost_analysis_dict,
    roofline_terms,
)

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

SAMPLE_HLO = """
  %all-reduce = f32[1024,512]{1,0} all-reduce(%x), channel_id=1, replica_groups=[2,8]<=[16], to_apply=%add
  %ag = bf16[16,4096]{1,0} all-gather(%y), channel_id=2, replica_groups=[4,4]<=[16], dimensions={0}
  %rs = f32[64]{0} reduce-scatter(%z), channel_id=3, replica_groups={{0,1,2,3}}, dimensions={0}
  %cp = bf16[128]{0} collective-permute(%w), channel_id=4, source_target_pairs={{0,1}}
  %other = f32[8] add(%a, %b)
"""


def test_collective_parse_kinds_and_ring_bytes():
    out = collective_bytes_from_hlo(SAMPLE_HLO)
    assert out["ops"] == 4
    # all-reduce: result 1024*512*4 B, K=8 -> 2*(7/8)*R
    r = 1024 * 512 * 4
    assert out["all-reduce"] == pytest.approx(2 * 7 / 8 * r)
    # all-gather: result 16*4096*2 B, K=4 -> (3/4)*R
    assert out["all-gather"] == pytest.approx(3 / 4 * 16 * 4096 * 2)
    # reduce-scatter: result 64*4 B, K=4 (explicit group) -> (K-1)*R
    assert out["reduce-scatter"] == pytest.approx(3 * 64 * 4)
    # collective-permute: R
    assert out["collective-permute"] == pytest.approx(128 * 2)
    assert out["total"] == pytest.approx(
        out["all-reduce"] + out["all-gather"] + out["reduce-scatter"]
        + out["collective-permute"]
    )


def test_collective_parse_multiplier():
    a = collective_bytes_from_hlo(SAMPLE_HLO, multiplier=3.0)
    b = collective_bytes_from_hlo(SAMPLE_HLO)
    assert a["total"] == pytest.approx(3 * b["total"])


def test_ring_formulas_k1_is_free():
    assert _ring_bytes("all-reduce", 100.0, 1) == 0.0


# ---------------------------------------------------------------------------
# the motivating defect: XLA cost_analysis counts scan bodies once
# ---------------------------------------------------------------------------


def test_xla_cost_analysis_undercounts_scans():
    """Documents WHY the roofline uses decomposed compilation: a scan of N
    matmuls reports ~1/N of the unrolled FLOPs."""
    w = jnp.zeros((64, 64))

    def f_scan(x):
        return jax.lax.scan(lambda c, _: (jnp.tanh(c @ w), None), x, None, length=16)[0]

    def f_unroll(x):
        for _ in range(16):
            x = jnp.tanh(x @ w)
        return x

    x = jnp.zeros((64, 64))
    fl_scan = cost_analysis_dict(jax.jit(f_scan).lower(x).compile())["flops"]
    fl_unroll = cost_analysis_dict(jax.jit(f_unroll).lower(x).compile())["flops"]
    assert fl_unroll > 10 * fl_scan  # would be ~equal if scans were counted


# ---------------------------------------------------------------------------
# methodology cross-check: decomposed sum == whole-model unrolled compile
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_decomposed_cost_matches_unrolled_whole_model():
    """For a tiny 4-layer model, per-layer-cost x 4 + tail must match the
    fully-unrolled single-module compile within tolerance."""
    import dataclasses

    from repro.configs import reduced_config
    from repro.models import init_model, loss_fn
    from repro.models.blocks import apply_block

    cfg = dataclasses.replace(
        reduced_config("internlm2-1.8b"), unroll_scans=True, remat=False,
    )
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    B, S = 4, 64
    batch = {
        "tokens": jnp.zeros((B, S), jnp.int32),
        "labels": jnp.zeros((B, S), jnp.int32),
    }

    # whole model, layers unrolled via num_layers separate apply calls
    def whole(params):
        return loss_fn(params, cfg, batch)[0]

    # force the layer scan to unroll by building a 1-layer-units config
    # (plan_scan_units gives one scan of 4 for the uniform pattern; compare
    # against manual unrolled application instead)
    from repro.models.layers import embed_lookup, chunked_cross_entropy, rmsnorm

    def manual(params):
        x = embed_lookup(params["embed"], batch["tokens"])
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        p_unit = params["decoder"][0]["sub0"]
        for layer in range(cfg.num_layers):
            p_l = jax.tree_util.tree_map(lambda a: a[layer], p_unit)
            x, _, _ = apply_block(
                p_l, x, cfg.blocks[0], cfg, positions=pos, cache=None,
                cur_pos=None,
            )
        x = rmsnorm(x, params["final_norm"])
        return chunked_cross_entropy(
            x, params["head"], batch["labels"], unroll=True
        )

    g_whole = jax.jit(jax.grad(whole))
    g_manual = jax.jit(jax.grad(manual))
    fl_scan = cost_analysis_dict(g_whole.lower(params).compile())["flops"]
    fl_manual = cost_analysis_dict(g_manual.lower(params).compile())["flops"]
    # manual-unrolled counts every layer; the scanned module counts one body.
    # Reconstruct: scan_total ~= per_layer x L (+ tails)
    per_layer_upper = fl_scan  # scan module ~ 1 body + tails
    assert fl_manual > 2.5 * per_layer_upper  # scan undercount visible
    # decomposition bound: manual total < (1 body + tails) * L
    assert fl_manual < fl_scan * cfg.num_layers * 1.5

    # numerics agree between the two formulations
    l1 = float(whole(params))
    l2 = float(manual(params))
    np.testing.assert_allclose(l1, l2, rtol=5e-4)  # bf16 reassociation


# ---------------------------------------------------------------------------
# sharding rules (duck-typed mesh — no devices needed)
# ---------------------------------------------------------------------------


class FakeMesh:
    def __init__(self, sizes):
        self.axis_names = tuple(sizes)
        self.devices = np.empty(tuple(sizes.values()))


def test_spec_rules_divisibility_fallbacks():
    from repro.sharding.rules import spec_for

    mesh = FakeMesh({"data": 16, "model": 16})
    # mixtral experts=8 not divisible by 16 -> falls through to mlp
    spec = spec_for((8, 4096, 14336), ("experts", "embed", "mlp"), mesh)
    assert tuple(spec) == (None, None, "model")
    # phi3.5 experts=16 divides -> EP
    spec = spec_for((16, 4096, 6400), ("experts", "embed", "mlp"), mesh)
    assert tuple(spec) == ("model", None, None)
    # hymba 25 heads -> row-parallel embed fallback
    spec = spec_for((1600, 25, 64), ("embed", "heads", "head_dim"), mesh)
    assert tuple(spec) == ("model", None, None)
    # never shard head_dim / layers
    spec = spec_for((32, 4096, 32, 128), ("layers", "embed", "heads", "head_dim"), mesh)
    assert tuple(spec) == (None, None, "model", None)


def test_with_zero_adds_dp_on_largest_free_dim():
    from jax.sharding import PartitionSpec as P

    from repro.sharding.rules import with_zero

    mesh = FakeMesh({"data": 16, "model": 16})
    spec = with_zero((32, 4096, 14336), P(None, None, "model"), mesh,
                     axes=("layers", "embed", "mlp"))
    assert tuple(spec) == (None, "data", "model")  # 4096 free -> data; L=32 skipped
    # multi-pod: both dp axes
    mesh2 = FakeMesh({"pod": 2, "data": 16, "model": 16})
    spec = with_zero((4096, 4096), P(None, "model"), mesh2)
    assert tuple(spec) == (("pod", "data"), "model")


def test_roofline_terms_bottleneck():
    t = roofline_terms(
        {"flops": 197e12, "bytes accessed": 819e9 * 2}, 50e9 * 0.5, 256, 1e15
    )
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(2.0)
    assert t.collective_s == pytest.approx(0.5)
    assert t.bottleneck == "memory"
