"""Serving engine correctness: prefill parity, sampling reproducibility,
q4 weight tolerance, and retire/backfill isolation.

Fast tier runs everything on a 2-layer tiny dense LM; the cross-arch prefill
parity cases (GQA + softcap, xLSTM recurrence, hybrid SSM) are compile-heavy
and carry the ``slow`` marker like the other decode-parity suites.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import (
    LayerSpec,
    ModelConfig,
    decode_step,
    init_model,
    init_serve_cache,
    prefill_with_cache,
)
from repro.models.attention import cache_prefill, cache_update, make_cache
from repro.serve import (
    Request,
    ServeEngine,
    materialize,
    prepare_params,
    request_key_words,
    sample_tokens,
    weight_report,
)

TINY = ModelConfig(
    name="serve-test",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=256,
    vocab_size=256,
    blocks=(LayerSpec("dense", 0),) * 2,
    remat=False,
)


@pytest.fixture(scope="module")
def tiny_params():
    params, _ = init_model(jax.random.PRNGKey(0), TINY)
    return params


def _oracle_prefill(params, cfg, prompts, s_max=64):
    """Token-at-a-time reference: feed each prompt through decode_step."""
    B = len(prompts)
    caches = init_serve_cache(cfg, B, s_max)
    S = max(len(p) for p in prompts)
    last = np.zeros((B, cfg.vocab_size), np.float32)
    for t in range(S):
        toks = jnp.array([p[min(t, len(p) - 1)] for p in prompts], jnp.int32)
        pos = jnp.full((B,), t, jnp.int32)
        logits, caches = decode_step(params, cfg, caches, toks, pos)
        logits = np.asarray(logits)
        for b, p in enumerate(prompts):
            if t == len(p) - 1:
                last[b] = logits[b]
    return last, caches


def _batched_prefill(params, cfg, prompts, s_max=64):
    B = len(prompts)
    S = max(len(p) for p in prompts)
    toks = np.zeros((B, S), np.int32)
    for b, p in enumerate(prompts):
        toks[b, : len(p)] = p
    lens = jnp.array([len(p) for p in prompts], jnp.int32)
    caches = init_serve_cache(cfg, B, s_max)
    logits, caches = prefill_with_cache(
        params, cfg, jnp.asarray(toks), lens, caches
    )
    return np.asarray(logits), caches


# ---------------------------------------------------------------------------
# one-shot prefill vs token-at-a-time oracle
# ---------------------------------------------------------------------------


def test_prefill_matches_decode_oracle_tiny(tiny_params):
    prompts = [[5, 6, 7, 8, 9], [10, 11, 12], [13]]
    l_oracle, c_oracle = _oracle_prefill(tiny_params, TINY, prompts)
    l_batch, c_batch = _batched_prefill(tiny_params, TINY, prompts)
    np.testing.assert_allclose(l_batch, l_oracle, atol=2e-2, rtol=0)

    # The caches must be behaviorally identical too: continue greedy decode
    # from both and compare every step's logits.
    pos = np.array([len(p) for p in prompts], np.int32)
    tok_a = jnp.asarray(np.argmax(l_oracle, -1).astype(np.int32))
    tok_b = jnp.asarray(np.argmax(l_batch, -1).astype(np.int32))
    for t in range(4):
        la, c_oracle = decode_step(
            tiny_params, TINY, c_oracle, tok_a, jnp.asarray(pos + t)
        )
        lb, c_batch = decode_step(
            tiny_params, TINY, c_batch, tok_b, jnp.asarray(pos + t)
        )
        np.testing.assert_allclose(
            np.asarray(lb), np.asarray(la), atol=2e-2, rtol=0
        )
        tok_a = jnp.argmax(la, -1).astype(jnp.int32)
        tok_b = jnp.argmax(lb, -1).astype(jnp.int32)
        assert np.array_equal(np.asarray(tok_a), np.asarray(tok_b))


@pytest.mark.slow
@pytest.mark.parametrize(
    "arch", ["gemma2-2b", "xlstm-125m", "hymba-1.5b"]
)
def test_prefill_matches_decode_oracle_archs(arch):
    # GQA + logit softcap / mLSTM + sLSTM recurrence / attention + SSM
    # hybrid: padding must be inert in every cache regime.
    cfg = reduced_config(arch)
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    prompts = [[5, 6, 7, 8], [9, 10]]
    l_oracle, _ = _oracle_prefill(params, cfg, prompts, s_max=256)
    l_batch, _ = _batched_prefill(params, cfg, prompts, s_max=256)
    # recurrent paths accumulate slightly different rounding than the
    # step-by-step oracle (bf16 matmuls in one S-length einsum vs S rank-1
    # updates); attention archs are bit-exact.
    np.testing.assert_allclose(l_batch, l_oracle, atol=5e-2, rtol=0)


def test_cache_prefill_matches_sequential_writes():
    # Gather-formulated bulk write == sequential circular writes, including
    # rows longer than the cache (windowed layers) and empty tails.
    B, S, Smax, H, D = 3, 10, 4, 2, 8
    key = jax.random.PRNGKey(1)
    k_new = jax.random.normal(key, (B, S, H, D))
    v_new = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, D))
    lengths = jnp.array([10, 3, 1], jnp.int32)

    bulk = cache_prefill(make_cache(B, Smax, H, D), k_new, v_new, lengths)

    seq = make_cache(B, Smax, H, D)
    for t in range(S):
        # sequential oracle writes row b only while t < lengths[b]; emulate
        # by re-writing the previous value for finished rows
        pos = jnp.minimum(t, lengths - 1)
        kt = k_new[jnp.arange(B), pos][:, None]
        vt = v_new[jnp.arange(B), pos][:, None]
        seq = cache_update(seq, kt, vt, pos)

    np.testing.assert_array_equal(np.asarray(bulk.pos), np.asarray(seq.pos))
    np.testing.assert_allclose(
        np.asarray(bulk.k, np.float32), np.asarray(seq.k, np.float32)
    )
    np.testing.assert_allclose(
        np.asarray(bulk.v, np.float32), np.asarray(seq.v, np.float32)
    )


# ---------------------------------------------------------------------------
# on-device sampling
# ---------------------------------------------------------------------------


def _rand_logits(key, B, V=64):
    return jax.random.normal(key, (B, V)) * 3.0


def test_sampling_greedy_at_zero_temperature():
    logits = _rand_logits(jax.random.PRNGKey(0), 4)
    kw = jnp.stack(request_key_words(0, np.arange(4)), axis=-1)
    out = sample_tokens(
        logits, kw, jnp.zeros(4, jnp.uint32), jnp.zeros(4), jnp.zeros(4, jnp.int32)
    )
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(jnp.argmax(logits, -1))
    )


def test_sampling_respects_top_k():
    B, V = 8, 64
    logits = _rand_logits(jax.random.PRNGKey(1), B, V)
    kw = jnp.stack(request_key_words(0, np.arange(B)), axis=-1)
    top_k = jnp.array([1, 2, 4, 8, 16, 1, 2, 4], jnp.int32)
    for gen in range(16):
        out = np.asarray(
            sample_tokens(
                logits, kw, jnp.full((B,), gen, jnp.uint32),
                jnp.full((B,), 0.9), top_k,
            )
        )
        ranks = np.asarray(jnp.argsort(-logits, axis=-1))
        for b in range(B):
            assert out[b] in ranks[b, : int(top_k[b])]


def test_sampling_stream_is_slot_invariant():
    # A request's stream depends on (seed, rid, gen_idx) only — not on which
    # batch row it occupies or who its neighbors are.
    V = 64
    logits_r7 = _rand_logits(jax.random.PRNGKey(7), 1, V)[0]
    for layout, row in ((np.array([7, 3]), 0), (np.array([9, 7, 1]), 1)):
        B = len(layout)
        logits = jnp.tile(logits_r7[None], (B, 1))
        kw = jnp.stack(request_key_words(0, layout), axis=-1)
        out = np.asarray(
            sample_tokens(
                logits, kw, jnp.full((B,), 5, jnp.uint32),
                jnp.full((B,), 0.8), jnp.full((B,), 10, jnp.int32),
            )
        )
        if row == 0:
            first = out[row]
        else:
            assert out[row] == first


def test_engine_sampled_streams_reproducible(tiny_params):
    # Same (seed, rid) => same stream, under slot reshuffle (reversed submit
    # order, different max_batch) and full engine restart.
    def serve(order, max_batch):
        eng = ServeEngine(TINY, tiny_params, max_batch=max_batch, s_max=64)
        reqs = {
            i: Request(
                rid=i, prompt=[1 + i, 2 + i, 3 + i], max_new_tokens=6,
                temperature=0.8, top_k=10,
            )
            for i in order
        }
        for i in order:
            eng.submit(reqs[i])
        eng.run()
        return {i: r.output for i, r in reqs.items()}

    a = serve([0, 1, 2, 3, 4], 2)
    b = serve([4, 3, 2, 1, 0], 3)  # reshuffled + different slot count
    c = serve([0, 1, 2, 3, 4], 2)  # restart
    assert a == b == c
    assert len({tuple(v) for v in a.values()}) > 1  # streams differ by rid


# ---------------------------------------------------------------------------
# q4 serving weights
# ---------------------------------------------------------------------------


def test_q4_within_logit_tolerance_of_bf16(tiny_params):
    prompts = [[5, 6, 7, 8], [9, 10]]
    l_bf, _ = _batched_prefill(
        materialize(prepare_params(tiny_params, "bf16")), TINY, prompts
    )
    l_q4, _ = _batched_prefill(
        materialize(prepare_params(tiny_params, "q4")), TINY, prompts
    )
    # bf16 serving == fp32 masters (casting to the compute dtype is a no-op
    # change); q4 adds bounded block-quantization noise, far below the O(1)
    # errors a broken scale/mapping layout produces.
    l_fp, _ = _batched_prefill(tiny_params, TINY, prompts)
    np.testing.assert_allclose(l_bf, l_fp, atol=1e-5, rtol=0)
    assert float(np.abs(l_q4 - l_bf).max()) < 0.3


def test_q4_weight_bytes_ratio(tiny_params):
    eng = ServeEngine(TINY, tiny_params, max_batch=2, s_max=64, weights="q4")
    rep = eng.weight_bytes()
    assert rep["quantized_leaves"] > 0
    assert rep["total_serve_bytes"] < rep["total_bf16_bytes"]
    # the acceptance floor holds on the GPT-2-M-shaped tree
    from benchmarks.tables import _gpt2m_like_params

    big = weight_report(_gpt2m_like_params(), "q4")
    assert big["ratio_vs_bf16"] >= 3.5


def test_q4_engine_decodes(tiny_params):
    eng = ServeEngine(TINY, tiny_params, max_batch=2, s_max=64, weights="q4")
    r = Request(rid=0, prompt=[3, 4, 5], max_new_tokens=5)
    eng.submit(r)
    eng.run()
    assert r.done and len(r.output) == 5
    assert all(0 <= t < TINY.vocab_size for t in r.output)


# ---------------------------------------------------------------------------
# retire / backfill isolation
# ---------------------------------------------------------------------------


def test_retire_backfill_no_kv_leak(tiny_params):
    # 6 requests through 2 slots (3 waves of retire + backfill), ragged
    # prompt lengths so buckets and cache occupancy differ per wave.  Every
    # stream must equal its solo single-slot run — any KV or sampler state
    # leaking across a backfill would diverge the later waves.
    prompts = [
        [5, 6, 7, 8, 9, 10, 11],
        [12, 13],
        [14, 15, 16],
        [17],
        [18, 19, 20, 21, 22],
        [23, 24, 25],
    ]

    def solo(i):
        eng = ServeEngine(TINY, tiny_params, max_batch=1, s_max=64)
        r = Request(rid=i, prompt=prompts[i], max_new_tokens=6)
        eng.submit(r)
        eng.run()
        return r.output

    eng = ServeEngine(TINY, tiny_params, max_batch=2, s_max=64)
    reqs = [
        Request(rid=i, prompt=prompts[i], max_new_tokens=6)
        for i in range(len(prompts))
    ]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for i, r in enumerate(reqs):
        assert r.done
        assert r.output == solo(i), f"rid={i} diverged after backfill"


def test_eos_retires_early(tiny_params):
    # Find the greedy second token, then declare it EOS: output must stop
    # there and the freed slot must serve the next request correctly.
    eng = ServeEngine(TINY, tiny_params, max_batch=1, s_max=64)
    probe = Request(rid=0, prompt=[7, 8, 9], max_new_tokens=4)
    eng.submit(probe)
    eng.run()
    eos = probe.output[1]

    eng = ServeEngine(TINY, tiny_params, max_batch=1, s_max=64)
    r0 = Request(rid=0, prompt=[7, 8, 9], max_new_tokens=4, eos_id=eos)
    r1 = Request(rid=1, prompt=[10, 11], max_new_tokens=3)
    eng.submit(r0)
    eng.submit(r1)
    eng.run()
    assert r0.done and r0.output == probe.output[:2]
    solo = ServeEngine(TINY, tiny_params, max_batch=1, s_max=64)
    ref = Request(rid=1, prompt=[10, 11], max_new_tokens=3)
    solo.submit(ref)
    solo.run()
    assert r1.output == ref.output
