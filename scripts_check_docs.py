#!/usr/bin/env python
"""Docs gate: intra-repo link check + executable fenced python snippets.

Two failure modes that rot a docs layer, both turned into CI failures:

* **Dead links** — every inline markdown link in ``docs/*.md`` whose target
  is not an external URL (``http(s)://``, ``mailto:``) or a pure fragment
  must resolve to an existing file, relative to the page that links it.
* **Stale code** — fenced ```` ```python ```` blocks are the *executable*
  convention (see ``docs/README.md``); each page's blocks are concatenated
  top to bottom and run in one subprocess with ``PYTHONPATH=src``, so an
  API drift that breaks a documented snippet breaks the build.  Plain
  ``` fences stay illustrative and are never executed.

    python scripts_check_docs.py            # check everything, exit 1 on rot
    python scripts_check_docs.py --no-run   # links only (fast)

Run from the repo root (the CI docs job does exactly this).
"""

from __future__ import annotations

import argparse
import glob
import os
import re
import subprocess
import sys
import time

DOCS_GLOB = os.path.join("docs", "*.md")
# inline links [text](target); images ![alt](target) match too via the [
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE_RE = re.compile(r"^```(\S*)\s*$")
_EXTERNAL = ("http://", "https://", "mailto:")


def check_links(path: str) -> list:
    """Dead intra-repo link targets in one markdown file."""
    dead = []
    base = os.path.dirname(path)
    text = open(path).read()
    # fenced blocks routinely contain ``foo[x](y)``-shaped code; strip them
    # so only prose links are checked
    prose = re.sub(r"```.*?```", "", text, flags=re.S)
    for target in _LINK_RE.findall(prose):
        if target.startswith(_EXTERNAL) or target.startswith("#"):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = os.path.normpath(os.path.join(base, rel))
        if not os.path.exists(resolved):
            dead.append(f"{path}: dead link -> {target}")
    return dead


def python_blocks(path: str) -> list:
    """The fenced ```python blocks of one file, in order."""
    blocks, cur, lang = [], None, None
    for line in open(path):
        m = _FENCE_RE.match(line.strip())
        if m and cur is None:
            lang, cur = m.group(1), []
        elif m:
            if lang == "python":
                blocks.append("".join(cur))
            cur, lang = None, None
        elif cur is not None:
            cur.append(line)
    return blocks


def run_snippets(path: str) -> tuple:
    """Execute a page's python blocks top to bottom in one process."""
    blocks = python_blocks(path)
    if not blocks:
        return True, 0, 0.0, ""
    script = "\n".join(
        f"# --- {path} block {i + 1} ---\n{b}" for i, b in enumerate(blocks)
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    t0 = time.perf_counter()
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, env=env, timeout=600,
    )
    wall = time.perf_counter() - t0
    out = (proc.stdout + proc.stderr).strip()
    return proc.returncode == 0, len(blocks), wall, out


def _step_summary(rows, failures) -> None:
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if not path:
        return
    lines = [
        "## docs gate",
        "",
        "| page | links | python blocks | snippet run |",
        "|---|---|---|---|",
    ]
    lines += [
        f"| {p} | {links} | {nblocks} | {status} |"
        for p, links, nblocks, status in rows
    ]
    lines += [
        "",
        f"**{len(failures)} failure(s)**" if failures else "Status: clean.",
    ]
    with open(path, "a") as f:
        f.write("\n".join(lines) + "\n")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--no-run", action="store_true",
        help="skip snippet execution (link check only)",
    )
    args = ap.parse_args()

    pages = sorted(glob.glob(DOCS_GLOB))
    if not pages:
        print("FAIL: no docs found at", DOCS_GLOB, file=sys.stderr)
        return 1

    failures, rows = [], []
    for page in pages:
        dead = check_links(page)
        failures += dead
        link_status = "ok" if not dead else f"{len(dead)} dead"
        if args.no_run:
            rows.append((page, link_status, "-", "skipped"))
            print(f"{page}: links {link_status}")
            continue
        ok, nblocks, wall, out = run_snippets(page)
        status = (
            "-" if nblocks == 0
            else f"ok ({wall:.1f}s)" if ok
            else "FAILED"
        )
        rows.append((page, link_status, nblocks or "-", status))
        print(f"{page}: links {link_status}, {nblocks} python block(s) {status}")
        if not ok:
            failures.append(f"{page}: snippet execution failed")
            print(out, file=sys.stderr)

    _step_summary(rows, failures)
    if failures:
        print(f"\nFAIL: {len(failures)} docs problem(s)", file=sys.stderr)
        for f in failures:
            print(" -", f, file=sys.stderr)
        return 1
    print(f"\nOK: {len(pages)} pages, links resolve, snippets run")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
