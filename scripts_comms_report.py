#!/usr/bin/env python
"""Bytes-on-the-wire report for the quantized gradient collective.

Renders the per-mode collective-traffic table (``repro.comms.accounting``)
for the GPT-2-M gradient tree — structural, computed from shapes alone, so
the figures are exact and identical on every platform:

    PYTHONPATH=src python scripts_comms_report.py

Prints ``name,us_per_call,derived`` CSV rows (the benchmark-suite idiom) and,
when ``$GITHUB_STEP_SUMMARY`` is set (the CI comms-matrix job), appends the
markdown table to the workflow step summary.  Exits nonzero if int4 transport
falls below the 4x compression floor — the same acceptance gate the drift
check enforces.
"""

from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from benchmarks.tables import _gpt2m_like_params  # noqa: E402
from repro.comms import format_wire_table, mode_totals  # noqa: E402

INT4_MIN_RATIO = 4.0


def main() -> int:
    params_s = _gpt2m_like_params()
    reports = mode_totals(params_s)

    for r in reports:
        print(
            f"comms/{r['mode']},0.0,"
            f"wire_bytes={r['total_wire_bytes']} "
            f"ratio_vs_fp32={r['ratio_vs_fp32']:.2f} "
            f"quantized_leaves={r['quantized_leaves']}/{r['n_leaves']}"
        )

    table = format_wire_table(
        reports, title="Gradient-collective bytes per step (GPT-2-M tree)"
    )
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write(table + "\n")
    else:
        print()
        print(table)

    int4 = next(r for r in reports if r["mode"] == "int4")
    if int4["ratio_vs_fp32"] < INT4_MIN_RATIO:
        print(
            f"FAIL: int4 transport ratio {int4['ratio_vs_fp32']:.2f}x is "
            f"below the {INT4_MIN_RATIO:.0f}x floor",
            file=sys.stderr,
        )
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
