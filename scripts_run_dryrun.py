import os, sys
sys.argv = ["dryrun"]
os.environ.setdefault("PYTHONPATH", "src")
from repro.launch.dryrun import run_all

ORDER = ["xlstm-125m", "internlm2-1.8b", "hymba-1.5b", "gemma2-2b",
         "qwen2-vl-2b", "qwen3-4b", "chatglm3-6b", "whisper-large-v3",
         "phi3.5-moe-42b-a6.6b", "mixtral-8x7b"]
run_all("results/dryrun.json", meshes=("single", "multi"), archs=ORDER)
print("DRYRUN SWEEP COMPLETE")
